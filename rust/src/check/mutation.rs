//! Deterministic schedule exploration and mutation self-tests.
//!
//! The real collections run on real threads, so their interleavings are
//! not reproducible. To *prove the checker can catch bugs* we need the
//! opposite: known-broken algorithms whose races manifest on demand.
//! This module re-expresses the Treiber stack and Michael–Scott queue as
//! **step-decomposed state machines** over a simulated arena, driven by
//! the DES engine ([`crate::sim::engine`]) in virtual time — every shared
//! mutation happens in exactly one engine step, every interleaving is a
//! pure function of the seed, and the produced [`History`] carries the
//! engine's virtual timestamps.
//!
//! Three deliberate mutations are provided:
//!
//! * [`Mutant::StackSplitCas`] — the stack pop's `compareAndSwapABA` is
//!   split into a compare step and a store step (check-then-act across a
//!   step boundary). Two poppers can both pass the compare and both take
//!   the same node: a duplicated pop the linearizability checker must
//!   flag.
//! * [`Mutant::QueueSplitCas`] — the same mis-ordering in the queue's
//!   head swing: one value dequeued twice.
//! * [`Mutant::SkipDeferGuard`] — pop frees its node immediately instead
//!   of routing it through `defer_delete`, while a *stalled pinned
//!   reader* (the adversarial schedule) still holds a reference it
//!   re-reads after the stall: a use-after-free the reclamation auditor
//!   must flag.
//!
//! Two **fault-masking** mutations model bugs the fault plane
//! ([`crate::fault`]) would smoke out — protocols that look correct
//! until the fabric duplicates a message or a lease clock runs fast:
//!
//! * [`Mutant::DupDefer`] — a duplicated `Defer` active message is
//!   applied twice (no sequence dedup at the home locale): the same
//!   node is retired twice and later freed twice, a double-free the
//!   auditor must flag.
//! * [`Mutant::EagerLeaseExpiry`] — the reclaimer "expires" the lease of
//!   readers that are alive and well and frees retired nodes under
//!   their open pins: a premature free (and, via the stalled reader, a
//!   use-after-free) the auditor must flag.
//!
//! `Mutant::None` runs the faithful decomposition and must pass both
//! checks — the self-test's control arm.

use super::audit::{ReclaimAudit, ReclaimAuditor, ViolationKind};
use super::history::{Completed, History, Op, Ret};
use super::spec::ModelKind;
use crate::obs::span::span_id;
use crate::obs::{Event, Tracer, INFRA_TASK};
use crate::pgas::{LocaleId, WidePtr};
use crate::sim::engine::{run, Step, VTime, Workload};
use crate::util::rng::Xoshiro256pp;
use std::sync::Arc;

/// Which deliberate bug (if any) to inject.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Mutant {
    None,
    StackSplitCas,
    QueueSplitCas,
    SkipDeferGuard,
    DupDefer,
    EagerLeaseExpiry,
}

impl Mutant {
    pub fn label(self) -> &'static str {
        match self {
            Mutant::None => "none",
            Mutant::StackSplitCas => "stack-split-cas",
            Mutant::QueueSplitCas => "queue-split-cas",
            Mutant::SkipDeferGuard => "skip-defer-guard",
            Mutant::DupDefer => "dup-defer",
            Mutant::EagerLeaseExpiry => "eager-lease-expiry",
        }
    }
}

/// Which structure the simulation runs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SimKind {
    Stack,
    Queue,
}

#[derive(Clone, Debug)]
pub struct SimCfg {
    pub kind: SimKind,
    pub mutant: Mutant,
    pub tasks: usize,
    pub ops_per_task: usize,
    /// Elements present before the concurrent phase (recorded as
    /// sequential events so the history stays self-contained).
    pub prepopulate: usize,
    pub seed: u64,
}

impl SimCfg {
    pub fn new(kind: SimKind, mutant: Mutant, seed: u64) -> SimCfg {
        SimCfg { kind, mutant, tasks: 4, ops_per_task: 60, prepopulate: 16, seed }
    }
}

/// Everything a self-test needs to judge one simulated run.
pub struct SimRun {
    pub history: History,
    pub auditor: Arc<ReclaimAuditor>,
    pub model: ModelKind,
}

// ---- simulated arena ----
//
// Slots are never deallocated (a "free" only drives the auditor's shadow
// state machine), so even mutant schedules that use-after-free remain
// memory-safe to *simulate* while being faithfully flagged.

const NIL: u64 = 0;

struct SimNode {
    val: u64,
    next: u64,
}

#[derive(Default)]
struct Arena {
    slots: Vec<SimNode>,
}

impl Arena {
    fn alloc(&mut self, val: u64, auditor: &ReclaimAuditor) -> u64 {
        self.slots.push(SimNode { val, next: NIL });
        let addr = (self.slots.len() as u64) * 16; // bit 0 free, non-nil
        auditor.on_alloc(wp(addr));
        addr
    }

    fn node(&self, addr: u64) -> &SimNode {
        &self.slots[(addr / 16 - 1) as usize]
    }

    fn node_mut(&mut self, addr: u64) -> &mut SimNode {
        &mut self.slots[(addr / 16 - 1) as usize]
    }
}

fn wp(addr: u64) -> WidePtr {
    WidePtr::new(LocaleId(0), addr)
}

// ---- step costs (virtual ns) ----

const C_ALLOC: VTime = 20;
const C_READ: VTime = 10;
const C_CAS: VTime = 15;
/// Extra delay the split-CAS mutants insert between compare and store —
/// the widened race window an adversarial schedule would seek out.
const C_SPLIT_GAP: VTime = 40;
/// How long the stalled reader holds its pin mid-operation.
const C_STALL: VTime = 4_000;
/// Offset added to every engine timestamp so prepopulation events
/// (stamped 1, 2, …) strictly precede the concurrent phase.
const T_BASE: VTime = 1_000_000;
/// Round period: task `t`'s op `k` never starts before `k * ROUND`.
/// Every task hits each round boundary within jitter of the others, so
/// contention concentrates exactly where the mutants race — while ops
/// from different rounds almost never overlap, keeping the history's
/// concurrent spans (and so the checker's search windows) task-count
/// sized instead of history-sized.
const ROUND: VTime = 1_000;

/// Per-task operation program entry.
#[derive(Copy, Clone, Debug)]
enum SimOp {
    Push(u64),
    Pop,
    Enq(u64),
    Deq,
    /// Pin, read the head pointer, stall, re-read it (audit-only; not a
    /// history event).
    Peek,
}

struct TaskSt {
    program: Vec<SimOp>,
    cur: usize,
    pc: u8,
    /// True between `begin_op` and `finish_op`. A CAS-failure retry
    /// re-enters pc 0; the guard keeps the op's invoke stamp (and its
    /// pin session) anchored at the FIRST attempt — re-stamping would
    /// shrink the interval and fabricate precedence.
    in_op: bool,
    invoke: VTime,
    // registers
    r_word: u64,
    r_count: u64,
    r_next: u64,
    r_node: u64,
    rng: Xoshiro256pp,
}

struct Sim {
    cfg: SimCfg,
    arena: Arena,
    auditor: Arc<ReclaimAuditor>,
    // stack head / queue head+tail, ABA-counted
    head: (u64, u64),
    tail: (u64, u64),
    /// Retired-but-not-freed addresses (freed after the run, like a
    /// final `clear`).
    limbo: Vec<u64>,
    /// Retires so far — drives [`Mutant::DupDefer`]'s deterministic
    /// "every Nth defer AM arrives twice" schedule.
    retires: u64,
    tasks: Vec<TaskSt>,
    history: History,
    /// Event sink; `None` keeps the schedule machinery on the exact
    /// untraced code (events are never built).
    tracer: Option<Arc<Tracer>>,
}

impl Sim {
    fn jit(&mut self, tid: usize, base: VTime) -> VTime {
        base + self.tasks[tid].rng.next_below(8)
    }

    /// Resume time after an operation completed: the next op waits for
    /// its round boundary (`finish_op` has already advanced `cur`).
    /// Retries stay on the tight `jit` path — rounds gate op *starts*,
    /// never the races within one.
    fn after_op(&mut self, tid: usize, now: VTime, cost: VTime) -> VTime {
        let round_start = self.tasks[tid].cur as VTime * ROUND;
        self.jit(tid, (now + cost).max(round_start))
    }

    fn begin_op(&mut self, tid: usize, now: VTime) {
        if self.tasks[tid].in_op {
            return; // retry re-entering pc 0: keep the original invoke/pin
        }
        self.tasks[tid].in_op = true;
        self.tasks[tid].invoke = now;
        // Every operation runs under a pin session, like the real
        // collections' token discipline.
        self.auditor.on_pin(tid, 1);
        if let Some(tr) = &self.tracer {
            let span = span_id(tid as u32, self.tasks[tid].cur as u64);
            tr.record_at(T_BASE + now, tid as u32, 0, Event::OpBegin { span });
            tr.record_at(T_BASE + now, tid as u32, 0, Event::Pin { epoch: 1 });
        }
    }

    fn finish_op(&mut self, tid: usize, now: VTime, record: Option<(Op, Ret)>) {
        if let Some((op, ret)) = record {
            self.history.push(Completed {
                task: tid,
                invoke: T_BASE + self.tasks[tid].invoke,
                response: T_BASE + now,
                op,
                ret,
            });
        }
        self.auditor.on_unpin(tid);
        if let Some(tr) = &self.tracer {
            let span = span_id(tid as u32, self.tasks[tid].cur as u64);
            let ns = now.saturating_sub(self.tasks[tid].invoke);
            tr.record_at(T_BASE + now, tid as u32, 0, Event::Unpin);
            tr.record_at(T_BASE + now, tid as u32, 0, Event::OpEnd { span, ns });
        }
        self.tasks[tid].in_op = false;
        self.tasks[tid].cur += 1;
        self.tasks[tid].pc = 0;
    }

    /// The deref a pinned operation performs: audit it, and put it on the
    /// trace (the record a UAF post-mortem greps for).
    fn access(&self, now: VTime, tid: usize, addr: u64) {
        self.auditor.on_access(wp(addr));
        if let Some(tr) = &self.tracer {
            tr.record_at(T_BASE + now, tid as u32, 0, Event::Access { addr });
        }
    }

    fn retire_or_free(&mut self, now: VTime, addr: u64) {
        match self.cfg.mutant {
            Mutant::SkipDeferGuard => {
                // The injected bug: bypass the epoch deferral entirely.
                self.auditor.on_free(wp(addr));
                if let Some(tr) = &self.tracer {
                    tr.record_at(T_BASE + now, INFRA_TASK, 0, Event::Free { addr });
                }
            }
            Mutant::DupDefer => {
                // The injected bug: the defer AM for every 4th retire is
                // duplicated by the fabric and the home locale applies it
                // twice — no sequence dedup. The node is retired twice
                // now and freed twice at the final clear.
                self.retires += 1;
                let copies = if self.retires % 4 == 0 { 2 } else { 1 };
                for _ in 0..copies {
                    self.auditor.on_retire(wp(addr), 1);
                    self.limbo.push(addr);
                    if let Some(tr) = &self.tracer {
                        tr.record_at(T_BASE + now, INFRA_TASK, 0, Event::Defer { dst: 0, list: 0 });
                    }
                }
            }
            Mutant::EagerLeaseExpiry => {
                // The injected bug: the home treats every reader's lease
                // as already expired and reclaims immediately — the
                // retiring task's own pin (and any stalled reader's) is
                // still open when the free lands.
                self.auditor.on_retire(wp(addr), 1);
                self.auditor.on_free(wp(addr));
                if let Some(tr) = &self.tracer {
                    tr.record_at(T_BASE + now, INFRA_TASK, 0, Event::Defer { dst: 0, list: 0 });
                    tr.record_at(T_BASE + now, INFRA_TASK, 0, Event::Free { addr });
                }
            }
            _ => {
                self.auditor.on_retire(wp(addr), 1);
                self.limbo.push(addr);
                if let Some(tr) = &self.tracer {
                    tr.record_at(T_BASE + now, INFRA_TASK, 0, Event::Defer { dst: 0, list: 0 });
                }
            }
        }
    }
}

impl Workload for Sim {
    fn step(&mut self, tid: usize, now: VTime) -> Step {
        let cur = self.tasks[tid].cur;
        if cur >= self.tasks[tid].program.len() {
            return Step::Done;
        }
        let op = self.tasks[tid].program[cur];
        let pc = self.tasks[tid].pc;
        match (op, pc) {
            // ---- stack push: alloc, read head, link+CAS ----
            (SimOp::Push(v), 0) => {
                self.begin_op(tid, now);
                self.tasks[tid].r_node = self.arena.alloc(v, &self.auditor);
                self.tasks[tid].pc = 1;
                Step::ResumeAt(self.jit(tid, now + C_ALLOC))
            }
            (SimOp::Push(_), 1) => {
                self.tasks[tid].r_word = self.head.0;
                self.tasks[tid].r_count = self.head.1;
                self.tasks[tid].pc = 2;
                Step::ResumeAt(self.jit(tid, now + C_READ))
            }
            (SimOp::Push(v), 2) => {
                let (node, ew, ec) =
                    (self.tasks[tid].r_node, self.tasks[tid].r_word, self.tasks[tid].r_count);
                self.arena.node_mut(node).next = ew; // unpublished: safe
                if self.head == (ew, ec) {
                    self.head = (node, ec + 1);
                    self.finish_op(tid, now, Some((Op::Push(v), Ret::Unit)));
                    return Step::ResumeAt(self.after_op(tid, now, C_CAS));
                }
                self.tasks[tid].pc = 1; // CAS failed: re-read
                Step::ResumeAt(self.jit(tid, now + C_CAS))
            }
            // ---- stack pop: read head, read next, CAS (maybe split) ----
            (SimOp::Pop, 0) => {
                self.begin_op(tid, now);
                self.tasks[tid].r_word = self.head.0;
                self.tasks[tid].r_count = self.head.1;
                if self.tasks[tid].r_word == NIL {
                    self.finish_op(tid, now, Some((Op::Pop, Ret::Val(None))));
                    return Step::ResumeAt(self.after_op(tid, now, C_READ));
                }
                self.tasks[tid].pc = 1;
                Step::ResumeAt(self.jit(tid, now + C_READ))
            }
            (SimOp::Pop, 1) => {
                let headw = self.tasks[tid].r_word;
                // The deref a real pop performs under its pin.
                self.access(now, tid, headw);
                self.tasks[tid].r_next = self.arena.node(headw).next;
                self.tasks[tid].pc = 2;
                Step::ResumeAt(self.jit(tid, now + C_READ))
            }
            (SimOp::Pop, 2) => {
                let (ew, ec, next) =
                    (self.tasks[tid].r_word, self.tasks[tid].r_count, self.tasks[tid].r_next);
                if self.cfg.mutant == Mutant::StackSplitCas {
                    // MUTATION: compare here, store in a later step.
                    if self.head == (ew, ec) {
                        self.tasks[tid].pc = 3;
                        return Step::ResumeAt(self.jit(tid, now + C_SPLIT_GAP));
                    }
                    self.tasks[tid].pc = 0;
                    return Step::ResumeAt(self.jit(tid, now + C_CAS));
                }
                if self.head == (ew, ec) {
                    self.head = (next, ec + 1);
                    let val = self.arena.node(ew).val;
                    self.retire_or_free(now, ew);
                    self.finish_op(tid, now, Some((Op::Pop, Ret::Val(Some(val)))));
                    return Step::ResumeAt(self.after_op(tid, now, C_CAS));
                }
                self.tasks[tid].pc = 0;
                Step::ResumeAt(self.jit(tid, now + C_CAS))
            }
            (SimOp::Pop, 3) => {
                // MUTATION (second half): blind store — the compare's
                // evidence may have rotted in the gap.
                let (ew, ec, next) =
                    (self.tasks[tid].r_word, self.tasks[tid].r_count, self.tasks[tid].r_next);
                self.head = (next, ec + 1);
                let val = self.arena.node(ew).val;
                self.retire_or_free(now, ew);
                self.finish_op(tid, now, Some((Op::Pop, Ret::Val(Some(val)))));
                Step::ResumeAt(self.after_op(tid, now, C_CAS))
            }
            // ---- queue enqueue: alloc, read tail, check next, link, swing ----
            (SimOp::Enq(v), 0) => {
                self.begin_op(tid, now);
                self.tasks[tid].r_node = self.arena.alloc(v, &self.auditor);
                self.tasks[tid].pc = 1;
                Step::ResumeAt(self.jit(tid, now + C_ALLOC))
            }
            (SimOp::Enq(_), 1) => {
                self.tasks[tid].r_word = self.tail.0;
                self.tasks[tid].r_count = self.tail.1;
                self.tasks[tid].pc = 2;
                Step::ResumeAt(self.jit(tid, now + C_READ))
            }
            (SimOp::Enq(_), 2) => {
                let (tw, tc) = (self.tasks[tid].r_word, self.tasks[tid].r_count);
                self.access(now, tid, tw);
                let next = self.arena.node(tw).next;
                if next != NIL {
                    // Tail lagging: help swing, then retry.
                    if self.tail == (tw, tc) {
                        self.tail = (next, tc + 1);
                    }
                    self.tasks[tid].pc = 1;
                } else {
                    self.tasks[tid].pc = 3;
                }
                Step::ResumeAt(self.jit(tid, now + C_CAS))
            }
            (SimOp::Enq(_), 3) => {
                let (tw, node) = (self.tasks[tid].r_word, self.tasks[tid].r_node);
                if self.arena.node(tw).next == NIL {
                    self.arena.node_mut(tw).next = node; // linearization
                    self.tasks[tid].pc = 4;
                } else {
                    self.tasks[tid].pc = 1;
                }
                Step::ResumeAt(self.jit(tid, now + C_CAS))
            }
            (SimOp::Enq(v), 4) => {
                let (tw, tc, node) =
                    (self.tasks[tid].r_word, self.tasks[tid].r_count, self.tasks[tid].r_node);
                if self.tail == (tw, tc) {
                    self.tail = (node, tc + 1); // swing (failure is fine)
                }
                self.finish_op(tid, now, Some((Op::Enq(v), Ret::Unit)));
                Step::ResumeAt(self.after_op(tid, now, C_CAS))
            }
            // ---- queue dequeue: read head, read next, CAS (maybe split) ----
            (SimOp::Deq, 0) => {
                self.begin_op(tid, now);
                self.tasks[tid].r_word = self.head.0;
                self.tasks[tid].r_count = self.head.1;
                self.tasks[tid].pc = 1;
                Step::ResumeAt(self.jit(tid, now + C_READ))
            }
            (SimOp::Deq, 1) => {
                let hw = self.tasks[tid].r_word;
                self.access(now, tid, hw);
                let next = self.arena.node(hw).next;
                if next == NIL {
                    self.finish_op(tid, now, Some((Op::Deq, Ret::Val(None))));
                    return Step::ResumeAt(self.after_op(tid, now, C_READ));
                }
                self.tasks[tid].r_next = next;
                self.tasks[tid].pc = 2;
                Step::ResumeAt(self.jit(tid, now + C_READ))
            }
            (SimOp::Deq, 2) => {
                let (hw, hc, next) =
                    (self.tasks[tid].r_word, self.tasks[tid].r_count, self.tasks[tid].r_next);
                if self.cfg.mutant == Mutant::QueueSplitCas {
                    if self.head == (hw, hc) {
                        self.tasks[tid].pc = 3;
                        return Step::ResumeAt(self.jit(tid, now + C_SPLIT_GAP));
                    }
                    self.tasks[tid].pc = 0;
                    return Step::ResumeAt(self.jit(tid, now + C_CAS));
                }
                if self.head == (hw, hc) {
                    self.head = (next, hc + 1);
                    self.access(now, tid, next);
                    let val = self.arena.node(next).val;
                    self.retire_or_free(now, hw); // old dummy
                    self.finish_op(tid, now, Some((Op::Deq, Ret::Val(Some(val)))));
                    return Step::ResumeAt(self.after_op(tid, now, C_CAS));
                }
                self.tasks[tid].pc = 0;
                Step::ResumeAt(self.jit(tid, now + C_CAS))
            }
            (SimOp::Deq, 3) => {
                // MUTATION (second half of the split head swing).
                let (hw, hc, next) =
                    (self.tasks[tid].r_word, self.tasks[tid].r_count, self.tasks[tid].r_next);
                self.head = (next, hc + 1);
                self.access(now, tid, next);
                let val = self.arena.node(next).val;
                self.retire_or_free(now, hw);
                self.finish_op(tid, now, Some((Op::Deq, Ret::Val(Some(val)))));
                Step::ResumeAt(self.after_op(tid, now, C_CAS))
            }
            // ---- stalled pinned reader (audit-only) ----
            (SimOp::Peek, 0) => {
                self.begin_op(tid, now);
                let hw = self.head.0;
                if hw == NIL {
                    self.finish_op(tid, now, None);
                    return Step::ResumeAt(self.after_op(tid, now, C_READ));
                }
                self.tasks[tid].r_word = hw;
                self.access(now, tid, hw);
                self.tasks[tid].pc = 1;
                // The stall: pinned, holding a reference, going nowhere.
                Step::ResumeAt(now + C_STALL)
            }
            (SimOp::Peek, 1) => {
                // Re-read the node the pin was supposed to protect.
                self.access(now, tid, self.tasks[tid].r_word);
                self.finish_op(tid, now, None);
                Step::ResumeAt(self.after_op(tid, now, C_READ))
            }
            (op, pc) => unreachable!("no step for {op:?} pc={pc}"),
        }
    }
}

/// Run one simulated schedule; deterministic in `cfg`.
pub fn run_sim(cfg: &SimCfg) -> SimRun {
    run_sim_traced(cfg, None)
}

/// [`run_sim`] with an optional event sink: op spans, pin/unpin, every
/// audited pointer access, deferrals — and, under
/// [`Mutant::SkipDeferGuard`], the rogue `Free` itself, so a detected
/// use-after-free reads straight off the trace as `free(addr)` followed
/// by `access(addr)`. `None` executes the exact untraced schedule.
pub fn run_sim_traced(cfg: &SimCfg, tracer: Option<Arc<Tracer>>) -> SimRun {
    let auditor = Arc::new(ReclaimAuditor::new());
    let mut arena = Arena::default();
    let mut history = Vec::new();
    let mut head = (NIL, 0);
    let mut tail = (NIL, 0);
    let mut stamp = 0;

    // Prepopulate sequentially, recording the matching events.
    match cfg.kind {
        SimKind::Stack => {
            for i in 0..cfg.prepopulate as u64 {
                let v = 900_000 + i;
                let node = arena.alloc(v, &auditor);
                arena.node_mut(node).next = head.0;
                head = (node, head.1 + 1);
                history.push(Completed {
                    task: 0,
                    invoke: stamp + 1,
                    response: stamp + 2,
                    op: Op::Push(v),
                    ret: Ret::Unit,
                });
                stamp += 2;
            }
        }
        SimKind::Queue => {
            let dummy = arena.alloc(0, &auditor);
            head = (dummy, 0);
            tail = (dummy, 0);
            for i in 0..cfg.prepopulate as u64 {
                let v = 900_000 + i;
                let node = arena.alloc(v, &auditor);
                arena.node_mut(tail.0).next = node;
                tail = (node, tail.1 + 1);
                history.push(Completed {
                    task: 0,
                    invoke: stamp + 1,
                    response: stamp + 2,
                    op: Op::Enq(v),
                    ret: Ret::Unit,
                });
                stamp += 2;
            }
        }
    }
    assert!(stamp < T_BASE, "prepopulation must precede the concurrent phase");

    // Per-task programs, generated in BALANCED PAIRS: each pair is one
    // write (push/enqueue) and one read (pop/dequeue) in a coin-flipped
    // order. Structure depth therefore stays within `prepopulate` ±
    // `tasks`, so the order ambiguity overlapping writes leave behind
    // (unobservable until a later pop/dequeue) cannot accumulate beyond
    // what the checker's DFS can afford to backtrack over — a biased
    // stream would let the structure (and with it the set of
    // order-ambiguous resident values) grow without bound. Under
    // SkipDeferGuard, task 0 is the stalled reader instead.
    let tasks: Vec<TaskSt> = (0..cfg.tasks)
        .map(|t| {
            let mut rng = Xoshiro256pp::new(cfg.seed ^ (t as u64).wrapping_mul(0x9E3779B9));
            let mut program: Vec<SimOp> = Vec::with_capacity(cfg.ops_per_task + 1);
            let mut i = 0;
            while i < cfg.ops_per_task {
                let v = (t as u64) * 100_000 + i as u64 + 1;
                let stalled_reader = cfg.kind == SimKind::Stack
                    && matches!(cfg.mutant, Mutant::SkipDeferGuard | Mutant::EagerLeaseExpiry)
                    && t == 0;
                let (wr, rd) = match cfg.kind {
                    SimKind::Stack => (SimOp::Push(v), SimOp::Pop),
                    SimKind::Queue => (SimOp::Enq(v), SimOp::Deq),
                };
                // One decision draw per pair for every task (the reader
                // included), so the jitter stream downstream is aligned
                // across mutants.
                let write_first = rng.chance(0.5);
                if stalled_reader {
                    program.push(SimOp::Peek);
                    program.push(SimOp::Peek);
                } else if write_first {
                    program.push(wr);
                    program.push(rd);
                } else {
                    program.push(rd);
                    program.push(wr);
                }
                i += 2;
            }
            TaskSt {
                program,
                cur: 0,
                pc: 0,
                in_op: false,
                invoke: 0,
                r_word: 0,
                r_count: 0,
                r_next: 0,
                r_node: 0,
                rng,
            }
        })
        .collect();

    let n_tasks = tasks.len();
    let mut sim = Sim {
        cfg: cfg.clone(),
        arena,
        auditor: Arc::clone(&auditor),
        head,
        tail,
        limbo: Vec::new(),
        retires: 0,
        tasks,
        history,
        tracer,
    };
    let (makespan, _) = run(&mut sim, n_tasks);

    // Final clear: every retired node is freed now that all tasks have
    // completed and unpinned (mirrors `EpochManager::clear`).
    let drained = sim.limbo.len() as u64;
    for addr in std::mem::take(&mut sim.limbo) {
        sim.auditor.on_free(wp(addr));
    }
    if drained > 0 {
        if let Some(tr) = &sim.tracer {
            tr.record_at(T_BASE + makespan, INFRA_TASK, 0, Event::Reclaim { n: drained });
        }
    }

    SimRun {
        history: sim.history,
        auditor,
        model: match cfg.kind {
            SimKind::Stack => ModelKind::Stack,
            SimKind::Queue => ModelKind::Queue,
        },
    }
}

/// Which oracle must fire for a seed to count as a detection.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Detector {
    /// Either oracle — the strictest *control* arm (nothing may fire).
    Any,
    /// The recorded history fails the linearizability check.
    NonLinearizable,
    /// The auditor reports a use-after-free.
    UseAfterFree,
    /// The auditor reports a double free (or double retire).
    DoubleFree,
    /// The auditor reports a free under a still-open pin session.
    PrematureFree,
}

/// Scan seeds until `det` fires for the given mutant; returns the first
/// such seed. Self-tests pin the EXPECTED oracle per mutant (a split
/// CAS also double-retires, so an `Any` scan would stay green off the
/// audit oracle alone even with a dead linearizability checker —
/// manufactured confidence), and assert `Mutant::None` never trips
/// `Any`.
pub fn first_seed_detected_by(
    kind: SimKind,
    mutant: Mutant,
    max_seeds: u64,
    det: Detector,
) -> Option<u64> {
    for seed in 0..max_seeds {
        let run = run_sim(&SimCfg::new(kind, mutant, seed));
        let hit = match det {
            Detector::Any => {
                super::linearize::check_history(run.model, &run.history).is_err()
                    || !run.auditor.ok()
            }
            Detector::NonLinearizable => {
                super::linearize::check_history(run.model, &run.history).is_err()
            }
            Detector::UseAfterFree => run
                .auditor
                .violations()
                .iter()
                .any(|v| v.kind == ViolationKind::UseAfterFree),
            Detector::DoubleFree => run
                .auditor
                .violations()
                .iter()
                .any(|v| v.kind == ViolationKind::DoubleFree),
            Detector::PrematureFree => run
                .auditor
                .violations()
                .iter()
                .any(|v| v.kind == ViolationKind::PrematureFree),
        };
        if hit {
            return Some(seed);
        }
    }
    None
}

/// [`first_seed_detected_by`] with [`Detector::Any`].
pub fn first_detecting_seed(kind: SimKind, mutant: Mutant, max_seeds: u64) -> Option<u64> {
    first_seed_detected_by(kind, mutant, max_seeds, Detector::Any)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::audit::ViolationKind;
    use crate::check::linearize::{check_history, minimize};

    #[test]
    fn unmutated_stack_and_queue_schedules_are_clean() {
        for kind in [SimKind::Stack, SimKind::Queue] {
            for seed in 0..10 {
                let run = run_sim(&SimCfg::new(kind, Mutant::None, seed));
                assert!(
                    check_history(run.model, &run.history).is_ok(),
                    "{kind:?} seed {seed}: faithful decomposition must be linearizable"
                );
                assert!(
                    run.auditor.ok(),
                    "{kind:?} seed {seed}: violations {:?}",
                    run.auditor.violations()
                );
                let c = run.auditor.counts();
                assert_eq!(c.pins, c.unpins, "every pin session closes");
                assert_eq!(c.retires, c.frees, "final clear frees every retired node");
            }
        }
    }

    #[test]
    fn runs_are_deterministic_in_the_seed() {
        let a = run_sim(&SimCfg::new(SimKind::Stack, Mutant::None, 7));
        let b = run_sim(&SimCfg::new(SimKind::Stack, Mutant::None, 7));
        assert_eq!(a.history, b.history);
        assert_eq!(a.auditor.counts(), b.auditor.counts());
        let c = run_sim(&SimCfg::new(SimKind::Stack, Mutant::None, 8));
        assert_ne!(a.history, c.history, "different seeds explore different schedules");
    }

    #[test]
    fn split_cas_stack_detected_as_non_linearizable() {
        let seed = first_detecting_seed(SimKind::Stack, Mutant::StackSplitCas, 20)
            .expect("split-CAS stack must be caught within 20 seeds");
        let run = run_sim(&SimCfg::new(SimKind::Stack, Mutant::StackSplitCas, seed));
        assert!(check_history(run.model, &run.history).is_err());
        // And the minimized counterexample is small enough to read.
        let min = minimize(run.model, &run.history);
        assert!(check_history(run.model, &min).is_err());
        assert!(min.len() <= 8, "minimized to {} events", min.len());
    }

    #[test]
    fn split_cas_queue_detected_as_non_linearizable() {
        let seed = first_detecting_seed(SimKind::Queue, Mutant::QueueSplitCas, 20)
            .expect("split-CAS queue must be caught within 20 seeds");
        let run = run_sim(&SimCfg::new(SimKind::Queue, Mutant::QueueSplitCas, seed));
        assert!(check_history(run.model, &run.history).is_err());
    }

    #[test]
    fn skipped_defer_guard_detected_as_use_after_free() {
        let seed = first_detecting_seed(SimKind::Stack, Mutant::SkipDeferGuard, 20)
            .expect("skipped defer_delete must be caught within 20 seeds");
        let run = run_sim(&SimCfg::new(SimKind::Stack, Mutant::SkipDeferGuard, seed));
        let v = run.auditor.violations();
        assert!(
            v.iter().any(|v| v.kind == ViolationKind::UseAfterFree),
            "expected a use-after-free, got {v:?}"
        );
    }

    #[test]
    fn dup_defer_detected_as_double_free() {
        // A duplicated Defer AM applied twice double-retires immediately
        // — seed 0 suffices; the bug is schedule-independent.
        let seed = first_seed_detected_by(SimKind::Stack, Mutant::DupDefer, 5, Detector::DoubleFree)
            .expect("dup-defer must be caught within 5 seeds");
        let run = run_sim(&SimCfg::new(SimKind::Stack, Mutant::DupDefer, seed));
        assert!(run
            .auditor
            .violations()
            .iter()
            .any(|v| v.kind == ViolationKind::DoubleFree && v.detail.contains("double retire")));
        // The history itself stays linearizable: without the auditor the
        // bug is invisible, which is exactly what makes it fault-masking.
        assert!(check_history(run.model, &run.history).is_ok());
    }

    #[test]
    fn eager_lease_expiry_detected_as_premature_free_and_uaf() {
        // Freeing under the retiring task's own open pin is premature on
        // the very first reclaim, whatever the schedule...
        let seed = first_seed_detected_by(
            SimKind::Stack,
            Mutant::EagerLeaseExpiry,
            5,
            Detector::PrematureFree,
        )
        .expect("eager lease expiry must be caught within 5 seeds");
        let run = run_sim(&SimCfg::new(SimKind::Stack, Mutant::EagerLeaseExpiry, seed));
        assert!(run
            .auditor
            .violations()
            .iter()
            .any(|v| v.kind == ViolationKind::PrematureFree));
        // ...and with the stalled pinned reader in the schedule, the
        // "expired" reader's re-read manifests as a real use-after-free.
        assert!(
            first_seed_detected_by(
                SimKind::Stack,
                Mutant::EagerLeaseExpiry,
                20,
                Detector::UseAfterFree,
            )
            .is_some(),
            "a stalled reader must eventually re-read a node freed under its lease"
        );
    }

    #[test]
    fn uaf_trace_shows_the_offending_free_then_access() {
        // Re-run the detecting seed with a tracer: the causal record of
        // the bug — a Free followed by a later Access of the SAME
        // address — must read straight off the trace.
        let seed = first_seed_detected_by(
            SimKind::Stack,
            Mutant::SkipDeferGuard,
            20,
            Detector::UseAfterFree,
        )
        .expect("a detecting seed exists");
        let tr = Arc::new(Tracer::new());
        let run = run_sim_traced(&SimCfg::new(SimKind::Stack, Mutant::SkipDeferGuard, seed), Some(tr.clone()));
        assert!(!run.auditor.ok());
        let events = tr.events();
        let culprit = events.iter().enumerate().any(|(i, e)| match e.ev {
            Event::Free { addr } => events[i..]
                .iter()
                .any(|later| matches!(later.ev, Event::Access { addr: a } if a == addr)),
            _ => false,
        });
        assert!(culprit, "trace must contain free(addr) … access(addr)");

        // Control arm: the faithful decomposition routes every retire
        // through the deferral path — its trace has NO Free events, and
        // the run's history/audit are untouched by tracing.
        let plain = run_sim(&SimCfg::new(SimKind::Stack, Mutant::None, seed));
        let trc = Arc::new(Tracer::new());
        let traced = run_sim_traced(&SimCfg::new(SimKind::Stack, Mutant::None, seed), Some(trc.clone()));
        assert_eq!(plain.history, traced.history, "tracing must not perturb the schedule");
        assert!(trc.events().iter().all(|e| !matches!(e.ev, Event::Free { .. })));
        assert!(trc.events().iter().any(|e| matches!(e.ev, Event::Defer { .. })));
    }
}
