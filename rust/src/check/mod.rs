//! Deterministic concurrency checking: the testbed's correctness oracle.
//!
//! The paper's claim is not that its structures are fast — it is that
//! non-blocking algorithms *plus* distributed epoch-based reclamation
//! stay **correct** under arbitrary interleavings of remote atomics and
//! deferred frees. This subsystem checks exactly that, two ways:
//!
//! * **Linearizability** ([`linearize`]): every concurrent history the
//!   collections produce (recorded by [`history::HistoryRecorder`] with
//!   virtual timestamps) must admit a sequential order, consistent with
//!   real-time precedence, that a `Vec`/`VecDeque`/`BTreeSet`/`BTreeMap`
//!   model ([`spec`]) reproduces — Wing–Gong checking with per-operation
//!   interval pruning.
//! * **Reclamation safety** ([`audit`]): a shadow lifecycle machine over
//!   every allocation, fed by hooks in the substrate and epoch manager,
//!   flags use-after-free, double-free, and frees that violate the EBR
//!   invariant (freeing under a pin session that was open at retire
//!   time).
//!
//! [`harness`] drives the four real collections under seeded adversarial
//! schedules; [`mutation`] replays deliberately-broken variants under
//! the DES engine to prove the oracle actually bites (`pgas-nb check
//! --mutate`).

pub mod audit;
pub mod harness;
pub mod history;
pub mod linearize;
pub mod mutation;
pub mod spec;

pub use audit::{AuditCounts, ReclaimAudit, ReclaimAuditor, Violation, ViolationKind};
pub use harness::{check_collection, check_collection_traced, CheckCfg, CheckOutcome, Collection};
pub use history::{render_history, Completed, History, HistoryRecorder, Op, Ret};
pub use linearize::{check_history, minimize, LinFailure};
pub use mutation::{
    first_detecting_seed, first_seed_detected_by, run_sim, run_sim_traced, Detector, Mutant,
    SimCfg, SimKind, SimRun,
};
pub use spec::{ModelKind, SeqModel};
