//! The checking harness: runs the *real* collections — `LockFreeStack`,
//! `LockFreeQueue`, `LockFreeList`, `InterlockedHashTable` — under seeded
//! concurrent churn with the [`HistoryRecorder`] wrapped around every
//! operation and a [`ReclaimAuditor`] attached to the substrate, then
//! judges the run: the recorded history must linearize against the
//! sequential model, the auditor must observe zero lifecycle violations,
//! and the heap must balance. Stack and queue churn issues balanced
//! push/pop pairs ([`pair_op_is_write`]) so structure depth — and with
//! it the linearization-order ambiguity the checker must search through
//! — stays bounded by the task count; list/map histories resolve their
//! ambiguity per key at every returned boolean.
//!
//! Adversarial knobs (the schedules most likely to expose an epoch or
//! ordering bug):
//!
//! * `stalled_reader` — one task repeatedly pins and *holds* the pin
//!   while everyone else churns and reclaims: epoch advances must abort
//!   (`NotQuiescent`) rather than free under the stale pin.
//! * `agg_capacity = 1` — every remote-owned deferral migrates
//!   immediately (maximum migration-flush traffic interleaved with
//!   drains); large capacities instead *delay* flushes to the elected
//!   advance. Both orderings must preserve the drain schedule.
//! * `topology` — hot-spot wirings (ring/dragonfly) reroute every remote
//!   charge; reclamation correctness must be invariant to geography.
//! * `hier_group` — the congestion-adaptive hierarchical advance: the
//!   election threads a group flag between the local and global ones and
//!   scans/drains fan out through group leaders, multiplying the
//!   interleavings between flag hand-offs and migration flushes. The
//!   drain schedule (and hence every lifecycle judgement) must be
//!   unchanged.

use super::audit::{ReclaimAuditor, Violation};
use super::history::{History, HistoryRecorder, Op, Ret};
use super::linearize::{self, LinFailure};
use super::spec::ModelKind;
use crate::collections::{InterlockedHashTable, LockFreeList, LockFreeQueue, LockFreeStack};
use crate::epoch::{EpochManager, ReclaimPolicy};
use crate::fabric::TopologyKind;
use crate::pgas::{coforall_locales, coforall_tasks, Machine, NicModel, Pgas};
use crate::util::rng::{SplitMix64, Xoshiro256pp};
use std::sync::Arc;

/// One checking run's configuration. `PartialEq` pins the trace-header
/// round trip: a config rebuilt from a trace's schedule section must
/// equal the one that produced it (`--trace-in` replays depend on this).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckCfg {
    pub seed: u64,
    pub locales: usize,
    pub tasks_per_locale: usize,
    /// Operations per (non-stalled) task; total history size is
    /// `locales * tasks_per_locale * ops_per_task` minus the reader.
    pub ops_per_task: usize,
    /// Key range for list/map workloads (small = high contention).
    pub key_space: u64,
    pub topology: TopologyKind,
    /// Deferral-aggregation capacity for the epoch manager (1 = flush on
    /// every remote deferral).
    pub agg_capacity: usize,
    /// `try_reclaim` every this many operations.
    pub reclaim_every: usize,
    /// Dedicate global task 0 to pin-stall-unpin cycles.
    pub stalled_reader: bool,
    /// Hierarchical-advance group size for the epoch manager (`None` =
    /// the flat protocol).
    pub hier_group: Option<usize>,
}

impl CheckCfg {
    /// A 1k-op history per collection: 2 locales × 2 tasks × 250 ops.
    pub fn quick(seed: u64) -> CheckCfg {
        CheckCfg {
            seed,
            locales: 2,
            tasks_per_locale: 2,
            ops_per_task: 250,
            key_space: 48,
            topology: TopologyKind::FlatZero,
            agg_capacity: crate::pgas::aggregation::default_capacity(),
            reclaim_every: 64,
            stalled_reader: false,
            hier_group: None,
        }
    }

    /// The adversarial variant: stalled pinned reader, immediate
    /// migration flushes, hot-spot dragonfly wiring.
    pub fn adversarial(seed: u64) -> CheckCfg {
        CheckCfg {
            topology: TopologyKind::Dragonfly,
            agg_capacity: 1,
            stalled_reader: true,
            reclaim_every: 16,
            ..CheckCfg::quick(seed)
        }
    }

    /// The congestion-adaptive hot-spot schedule: everything
    /// [`CheckCfg::adversarial`] throws at the manager, plus the
    /// hierarchical (group-leader) epoch advance, so elections race
    /// through three flags instead of two while migration flushes and
    /// the stalled pin interleave with the leader fan-out.
    pub fn adaptive(seed: u64) -> CheckCfg {
        CheckCfg { hier_group: Some(2), ..CheckCfg::adversarial(seed) }
    }
}

/// Balanced-pair op choice for the stack/queue workloads: ops `2k` and
/// `2k+1` of a task are one write (push/enqueue) and one read (pop/
/// dequeue) in a coin-flipped order, decided by a pure function of
/// (seed, task, pair) so both halves of a pair agree without sharing
/// state. Balance keeps structure depth bounded by the task count, so
/// the order ambiguity that overlapping writes leave behind (invisible
/// until a later read observes it) cannot accumulate beyond what the
/// linearizability DFS affords to backtrack over — see the
/// [`super::linearize`] module docs. Returns whether op `i` is a write.
fn pair_op_is_write(seed: u64, g: usize, i: usize) -> bool {
    let pair = (i / 2) as u64;
    let coin = SplitMix64::new(seed ^ ((g as u64) << 40).wrapping_add(pair)).next_u64() & 1 == 0;
    coin == (i % 2 == 0)
}

/// Which real collection to drive.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Collection {
    Stack,
    Queue,
    List,
    Map,
}

impl Collection {
    pub const ALL: [Collection; 4] =
        [Collection::Stack, Collection::Queue, Collection::List, Collection::Map];

    pub fn label(self) -> &'static str {
        self.model().label()
    }

    pub fn model(self) -> ModelKind {
        match self {
            Collection::Stack => ModelKind::Stack,
            Collection::Queue => ModelKind::Queue,
            Collection::List => ModelKind::Set,
            Collection::Map => ModelKind::Map,
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<Collection> {
        match s {
            "stack" => Some(Collection::Stack),
            "queue" => Some(Collection::Queue),
            "list" | "set" => Some(Collection::List),
            "map" | "table" => Some(Collection::Map),
            _ => None,
        }
    }
}

/// The judged result of one run.
pub struct CheckOutcome {
    pub collection: Collection,
    pub history: History,
    pub lin: Result<(), LinFailure>,
    /// Present iff `lin` failed: the fixed-point-minimized counterexample.
    pub minimized: Option<History>,
    pub violations: Vec<Violation>,
    /// Heap objects still live after teardown (must be 0).
    pub leaked: i64,
}

impl CheckOutcome {
    pub fn passed(&self) -> bool {
        self.lin.is_ok() && self.violations.is_empty() && self.leaked == 0
    }
}

/// Drive `collection` under `cfg` and judge the run.
pub fn check_collection(collection: Collection, cfg: &CheckCfg) -> CheckOutcome {
    check_collection_traced(collection, cfg, None)
}

/// [`check_collection`] with an optional event sink attached to the
/// substrate: AM sends/deliveries, epoch pins/unpins/advances, deferral
/// and reclaim events all land in the trace, so a failing run ships a
/// causal record alongside its minimized history. `None` leaves every
/// hot path on the untraced code.
pub fn check_collection_traced(
    collection: Collection,
    cfg: &CheckCfg,
    tracer: Option<Arc<crate::obs::Tracer>>,
) -> CheckOutcome {
    assert!(
        !cfg.stalled_reader || cfg.locales * cfg.tasks_per_locale >= 2,
        "stalled_reader dedicates task 0 to stalling; with no worker left the \
         run would record an empty history and pass vacuously"
    );
    let machine = Machine::new(cfg.locales, cfg.tasks_per_locale);
    let pgas = Pgas::with_topology(
        machine,
        NicModel::aries_no_network_atomics(),
        cfg.topology.build(cfg.locales),
    );
    if let Some(tr) = tracer {
        assert!(pgas.set_tracer(tr), "fresh Pgas accepts a tracer");
    }
    let auditor = Arc::new(ReclaimAuditor::new());
    assert!(pgas.set_audit(Arc::clone(&auditor) as _), "fresh Pgas accepts an auditor");
    let recorder = HistoryRecorder::new();

    let history = {
        let em = EpochManager::with_full_config(
            Arc::clone(&pgas),
            ReclaimPolicy::default(),
            cfg.agg_capacity,
            cfg.hier_group,
        );
        match collection {
            Collection::Stack => {
                let s = LockFreeStack::new(Arc::clone(&pgas), em.clone());
                drive(cfg, &em, |g, i, _rng, tok| {
                    if pair_op_is_write(cfg.seed, g, i) {
                        let v = g as u64 * 1_000_000 + i as u64 + 1;
                        recorder.record(g, Op::Push(v), || {
                            s.push(tok, v);
                            Ret::Unit
                        });
                    } else {
                        recorder.record(g, Op::Pop, || Ret::Val(s.pop(tok)));
                    }
                });
            }
            Collection::Queue => {
                let q = LockFreeQueue::new(Arc::clone(&pgas), em.clone());
                drive(cfg, &em, |g, i, _rng, tok| {
                    if pair_op_is_write(cfg.seed, g, i) {
                        let v = g as u64 * 1_000_000 + i as u64 + 1;
                        recorder.record(g, Op::Enq(v), || {
                            q.enqueue(tok, v);
                            Ret::Unit
                        });
                    } else {
                        recorder.record(g, Op::Deq, || Ret::Val(q.dequeue(tok)));
                    }
                });
            }
            Collection::List => {
                let l = LockFreeList::new(Arc::clone(&pgas), em.clone());
                drive(cfg, &em, |g, _i, rng, tok| {
                    let k = 1 + rng.next_below(cfg.key_space);
                    match rng.next_below(10) {
                        0..=3 => recorder.record(g, Op::SetInsert(k), || {
                            Ret::Bool(l.insert(tok, k))
                        }),
                        4..=6 => recorder.record(g, Op::SetRemove(k), || {
                            Ret::Bool(l.remove(tok, k))
                        }),
                        _ => recorder.record(g, Op::SetContains(k), || {
                            Ret::Bool(l.contains(tok, k))
                        }),
                    };
                });
            }
            Collection::Map => {
                let h: InterlockedHashTable<u64> =
                    InterlockedHashTable::new(Arc::clone(&pgas), em.clone(), cfg.locales * 8);
                drive(cfg, &em, |g, _i, rng, tok| {
                    let k = 1 + rng.next_below(cfg.key_space);
                    match rng.next_below(10) {
                        0..=3 => {
                            let v = k * 1_000_000 + g as u64;
                            recorder.record(g, Op::MapInsert(k, v), || {
                                Ret::Bool(h.insert(tok, k, v))
                            })
                        }
                        4..=5 => recorder.record(g, Op::MapRemove(k), || {
                            Ret::Bool(h.remove(tok, k))
                        }),
                        _ => recorder.record(g, Op::MapGet(k), || Ret::Val(h.get(tok, k))),
                    };
                });
            }
        }
        // Reclaim everything still deferred, then tear the structure and
        // manager down (scope end) so the heap must balance.
        em.clear();
        recorder.take()
    };

    let model = collection.model();
    let lin = linearize::check_history(model, &history);
    let minimized = match lin.as_ref().err() {
        None => None,
        // UNDECIDED (state-cap) failures carry an empty window and would
        // make every shrink candidate as expensive as the original run.
        Some(f) if f.window.is_empty() => None,
        // Prefer shrinking the localized window (orders of magnitude
        // smaller than the run); its failure can depend on prefix state,
        // so fall back to the full history if it passes alone.
        Some(f) => Some(if linearize::check_history(model, &f.window).is_err() {
            linearize::minimize(model, &f.window)
        } else {
            linearize::minimize(model, &history)
        }),
    };
    CheckOutcome {
        collection,
        lin,
        minimized,
        violations: auditor.violations(),
        leaked: pgas.live_objects(),
        history,
    }
}

/// Run `op` across `locales × tasks_per_locale` real tasks (global task
/// id, per-op index, the task's RNG, its epoch token), plus the optional
/// stalled reader on global task 0.
fn drive(
    cfg: &CheckCfg,
    em: &EpochManager,
    op: impl Fn(usize, usize, &mut Xoshiro256pp, &crate::epoch::EpochToken) + Sync,
) {
    use std::sync::atomic::{AtomicUsize, Ordering};
    // Ops completed by the worker tasks; the stalled reader paces its
    // pin sessions against this, not wall time.
    let progress = AtomicUsize::new(0);
    let workers =
        cfg.locales * cfg.tasks_per_locale - usize::from(cfg.stalled_reader);
    let total_ops = workers * cfg.ops_per_task;
    coforall_locales(Machine::new(cfg.locales, cfg.tasks_per_locale), |loc| {
        coforall_tasks(cfg.tasks_per_locale, |tid| {
            let g = loc.index() * cfg.tasks_per_locale + tid;
            let tok = em.register();
            if cfg.stalled_reader && g == 0 {
                // The adversarial schedule: hold a pin while the rest of
                // the machine churns and tries to reclaim. Each session
                // stays open until the peers have made REAL progress
                // (~a tenth of the run) — a fixed-length spin would
                // usually close before the first retire even lands, and
                // any free of an object retired during an open session
                // would be flagged as premature by the auditor.
                for _ in 0..8 {
                    tok.pin();
                    let target =
                        (progress.load(Ordering::Relaxed) + total_ops / 10).min(total_ops);
                    while progress.load(Ordering::Relaxed) < target {
                        std::thread::yield_now();
                    }
                    tok.unpin();
                }
                return;
            }
            let mut rng = Xoshiro256pp::new(cfg.seed ^ (g as u64).wrapping_mul(0xD6E8FEB8));
            for i in 0..cfg.ops_per_task {
                op(g, i, &mut rng, &tok);
                progress.fetch_add(1, Ordering::Relaxed);
                if cfg.reclaim_every > 0 && (i + 1) % cfg.reclaim_every == 0 {
                    tok.try_reclaim();
                }
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_queue_pass_quick_check() {
        for c in [Collection::Stack, Collection::Queue] {
            let out = check_collection(c, &CheckCfg::quick(11));
            assert!(out.lin.is_ok(), "{}: {:?}", c.label(), out.lin.as_ref().err());
            assert!(out.violations.is_empty(), "{}: {:?}", c.label(), out.violations);
            assert_eq!(out.leaked, 0, "{} leaked", c.label());
            assert!(out.history.len() > 500, "history recorded");
            assert!(out.passed());
        }
    }

    #[test]
    fn list_and_map_pass_quick_check() {
        for c in [Collection::List, Collection::Map] {
            let out = check_collection(c, &CheckCfg::quick(12));
            assert!(out.lin.is_ok(), "{}: {:?}", c.label(), out.lin.as_ref().err());
            assert!(out.violations.is_empty(), "{}: {:?}", c.label(), out.violations);
            assert_eq!(out.leaked, 0);
        }
    }

    #[test]
    fn adversarial_schedule_passes_and_actually_stalls() {
        let out = check_collection(Collection::Stack, &CheckCfg::adversarial(13));
        assert!(out.passed(), "lin={:?} violations={:?}", out.lin.as_ref().err(), out.violations);
        // The stalled reader really did open pin sessions.
        assert!(out.history.len() > 100);
    }

    #[test]
    fn adaptive_hot_spot_schedule_passes_the_checker() {
        // The hierarchical advance must not perturb any judged property:
        // histories stay linearizable, no lifecycle violation, heap
        // balances — under the same adversarial stall/flush schedule.
        for (c, seed) in [(Collection::Stack, 14), (Collection::Map, 15)] {
            let cfg = CheckCfg::adaptive(seed);
            assert_eq!(cfg.hier_group, Some(2));
            let out = check_collection(c, &cfg);
            assert!(
                out.passed(),
                "{}: lin={:?} violations={:?} leaked={}",
                c.label(),
                out.lin.as_ref().err(),
                out.violations,
                out.leaked
            );
        }
    }

    #[test]
    fn traced_check_judges_identically_and_records_the_epoch_lifecycle() {
        let plain = check_collection(Collection::Stack, &CheckCfg::quick(11));
        let tr = Arc::new(crate::obs::Tracer::new());
        let out = check_collection_traced(Collection::Stack, &CheckCfg::quick(11), Some(tr.clone()));
        assert!(out.passed());
        // Scheduling is thread-timing dependent, but the verdict and the
        // heap books must agree with the untraced run.
        assert_eq!(out.leaked, plain.leaked);
        assert_eq!(out.history.len(), plain.history.len());
        let kinds: std::collections::HashSet<&'static str> =
            tr.events().iter().map(|e| e.ev.kind()).collect();
        for k in ["pin", "unpin", "defer", "reclaim"] {
            assert!(kinds.contains(k), "trace missing {k}: {kinds:?}");
        }
    }

    #[test]
    fn collection_parse_roundtrip() {
        for c in Collection::ALL {
            assert_eq!(Collection::parse(c.label()), Some(c));
        }
        assert_eq!(Collection::parse("table"), Some(Collection::Map));
        assert_eq!(Collection::parse("bogus"), None);
    }
}
