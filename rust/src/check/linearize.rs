//! A Wing–Gong/Lowe-style linearizability checker: a single memoized
//! just-in-time DFS over the whole history, with interval pruning.
//!
//! Given a complete concurrent [`History`] and a [`ModelKind`], decide
//! whether the operations can be totally ordered such that (a) the order
//! respects real-time precedence (`response_a < invoke_b` ⇒ a before b)
//! and (b) the sequential model reproduces every observed return.
//!
//! ## Search structure
//!
//! 1. **JIT candidate rule.** With events sorted by invocation, the next
//!    linearized op must be invoked no later than the earliest pending
//!    response (Wing–Gong). Because pending ops below the completed
//!    prefix are bounded by genuine concurrency, the candidate window at
//!    any point is a handful of ops, scanned from the first unlinearized
//!    index — never the whole history.
//! 2. **Memoized DFS.** One depth-first search over the entire history,
//!    trying candidates in invocation order and backtracking when an
//!    observed return refutes the guessed order. A visited set keyed by
//!    (linearized-set, exact model state) collapses re-exploration
//!    (Lowe's just-in-time cache). One witness suffices: the search
//!    returns as soon as every op is linearized.
//!
//! A single whole-history DFS — rather than materializing, chunk by
//! chunk, *every* model state a prefix can reach — matters: overlapping
//! stack pushes or queue enqueues leave their order ambiguous until a
//! later pop/dequeue observes it, and a frontier of all reachable states
//! grows as 2^(unresolved pairs). The DFS instead guesses one order and
//! pays a bounded backtrack only when a later observation refutes it.
//! The checking workloads keep structure depth bounded (balanced
//! push/pop pairs in [`crate::check::harness`] and
//! [`crate::check::mutation`]) so unresolved ambiguity — and with it the
//! search frontier — stays small; `MAX_VISITED_STATES` turns any
//! pathological history into a loud failure rather than a hang.
//!
//! Failing histories are localized to the *chunk* (maximal span of
//! overlapping intervals, see [`chunk_ranges`]) where the deepest search
//! path got stuck, then minimized with the fixed-point shrinker from
//! [`crate::util::proptest`], so a reported counterexample is a locally
//! minimal set of events that is still non-linearizable.

use super::history::{render_history, Completed, History};
use super::spec::{ModelKind, SeqModel};
use crate::util::proptest::shrink_to_fixed_point;
use std::collections::HashSet;

/// Upper bound on distinct (linearized-set, model-state) pairs explored
/// per history. The bounded-depth workloads stay orders of magnitude
/// below it (worst observed ≈ 2^19); hit only by adversarial
/// dense-ambiguity inputs, and then the check returns an UNDECIDED
/// failure (empty window) rather than silently approximating — or
/// panicking mid-gate, which would skip the CLI's table and artifact
/// paths.
const MAX_VISITED_STATES: usize = 1 << 22;

/// Memory budget for the visited set (each entry clones the bitset plus
/// the model canon, so long histories hit memory before the state
/// count): the effective cap is scaled down so UNDECIDED is returned
/// before the allocator kills the process and skips the CLI's table and
/// artifact paths.
const MAX_VISITED_BYTES: usize = 1 << 30;

/// Why a history failed the check.
#[derive(Clone, Debug)]
pub struct LinFailure {
    /// Index range (into the invocation-sorted history) of the chunk of
    /// overlapping operations where the deepest linearization attempt
    /// got stuck.
    pub chunk: (usize, usize),
    /// The offending events.
    pub window: History,
    pub message: String,
}

impl std::fmt::Display for LinFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "{} (events {}..={}):", self.message, self.chunk.0, self.chunk.1)?;
        f.write_str(&render_history(&self.window))
    }
}

/// Fixed-size-word bitset over the history's ops.
type Bits = Vec<u64>;

#[inline]
fn bit_set(bits: &mut [u64], i: usize) {
    bits[i / 64] |= 1 << (i % 64);
}

#[inline]
fn bit_get(bits: &[u64], i: usize) -> bool {
    bits[i / 64] & (1 << (i % 64)) != 0
}

/// WGL candidate rule: pending ops whose invocation is no later than the
/// earliest pending response. (`<=` rather than `<` tolerates the DES
/// testbed's tied virtual timestamps conservatively — a tie is treated
/// as overlap, never as precedence.)
///
/// `hist` is invocation-sorted and every op below `lo` is linearized, so
/// the scan starts at `lo` and stops at the first op invoked after the
/// running minimum pending response: a later-invoked op can neither be a
/// candidate itself (its invoke only grows) nor disqualify an earlier
/// one (its response is at least its invoke).
fn candidates(hist: &[Completed], done: &[u64], lo: usize) -> Vec<usize> {
    let mut min_resp = u64::MAX;
    let mut window = Vec::new();
    let mut i = lo;
    while i < hist.len() && hist[i].invoke <= min_resp {
        if !bit_get(done, i) {
            window.push(i);
            min_resp = min_resp.min(hist[i].response);
        }
        i += 1;
    }
    window.retain(|&j| hist[j].invoke <= min_resp);
    window
}

struct Frame {
    bits: Bits,
    model: SeqModel,
    cands: Vec<usize>,
    next: usize,
    /// First index not yet linearized (every op below it is).
    lo: usize,
    /// Number of linearized ops.
    count: usize,
}

/// Split the invocation-sorted history at every point where all earlier
/// responses strictly precede all later invocations. Returns index
/// ranges `[start, end)`. (Used to localize failures; the DFS itself
/// crosses chunk boundaries freely, which is what lets it revisit an
/// earlier ambiguous order when a later chunk refutes it.)
fn chunk_ranges(hist: &[Completed]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut start = 0;
    let mut max_resp = 0;
    for (i, e) in hist.iter().enumerate() {
        if i > start && max_resp < e.invoke {
            ranges.push((start, i));
            start = i;
        }
        max_resp = max_resp.max(e.response);
    }
    if start < hist.len() {
        ranges.push((start, hist.len()));
    }
    ranges
}

/// Check `hist` (any order; sorted internally) against `kind`'s
/// sequential model. `Ok(())` iff linearizable.
pub fn check_history(kind: ModelKind, hist: &History) -> Result<(), LinFailure> {
    let mut hist = hist.clone();
    hist.sort_by_key(|e| (e.invoke, e.response));
    for e in &hist {
        assert!(e.invoke <= e.response, "malformed event: {e}");
    }
    let n = hist.len();
    if n == 0 {
        return Ok(());
    }
    let words = n.div_ceil(64);
    // Per-entry estimate: bitset words + canon/hash-table overhead.
    let max_states = MAX_VISITED_STATES.min(MAX_VISITED_BYTES / (words * 8 + 96));
    let mut visited: HashSet<(Bits, Vec<u64>)> = HashSet::new();
    // Deepest stuck point seen: (linearized count, first unlinearized index).
    let mut deepest = (0usize, 0usize);
    let bits0 = vec![0u64; words];
    let mut stack = vec![Frame {
        cands: candidates(&hist, &bits0, 0),
        bits: bits0,
        model: SeqModel::new(kind),
        next: 0,
        lo: 0,
        count: 0,
    }];
    while let Some(frame) = stack.last_mut() {
        if frame.next >= frame.cands.len() {
            stack.pop();
            continue;
        }
        let i = frame.cands[frame.next];
        frame.next += 1;
        let mut model = frame.model.clone();
        if model.apply(&hist[i].op) != hist[i].ret {
            continue; // observed return refutes this order
        }
        let mut bits = frame.bits.clone();
        bit_set(&mut bits, i);
        let count = frame.count + 1;
        if count == n {
            return Ok(()); // a witness linearization exists
        }
        let mut lo = frame.lo;
        while bit_get(&bits, lo) {
            lo += 1;
        }
        if count > deepest.0 {
            deepest = (count, lo);
        }
        if visited.len() >= max_states {
            // Fail-safe, never fail-silent: we could not PROVE a witness
            // exists, so the gate must go red — but with an explicit
            // UNDECIDED verdict (empty window), not a fabricated
            // non-linearizability claim, and not a panic.
            return Err(LinFailure {
                chunk: (0, n - 1),
                window: Vec::new(),
                message: format!(
                    "linearizability UNDECIDED: search exceeded {max_states} states \
                     (history ambiguity denser than this checker handles)"
                ),
            });
        }
        if !visited.insert((bits.clone(), model.canon())) {
            continue; // state already explored
        }
        let cands = candidates(&hist, &bits, lo);
        stack.push(Frame { bits, model, cands, next: 0, lo, count });
    }
    let (start, end) = chunk_ranges(&hist)
        .into_iter()
        .find(|&(s, t)| s <= deepest.1 && deepest.1 < t)
        .unwrap_or((0, n));
    Err(LinFailure {
        chunk: (start, end - 1),
        window: hist[start..end].to_vec(),
        message: format!(
            "history is NOT linearizable w.r.t. the sequential {} model",
            kind.label()
        ),
    })
}

/// Shrink candidates for a history: both halves plus EVERY single-event
/// removal. The generic [`crate::util::proptest::shrink_vec`] tries only
/// three removal positions (first/middle/last) to stay cheap for
/// property tests; the minimality [`minimize`] promises — *no* single
/// removal still fails — needs them all.
fn shrink_history(h: &History) -> Vec<History> {
    let n = h.len();
    let mut out = Vec::new();
    if n == 0 {
        return out;
    }
    if n > 1 {
        out.push(h[..n / 2].to_vec());
        out.push(h[n / 2..].to_vec());
    }
    for i in 0..n {
        let mut c = h.clone();
        c.remove(i);
        out.push(c);
    }
    out
}

/// Minimize a failing history: repeatedly drop events while the remainder
/// still fails the check, iterated to a fixed point — no single further
/// removal keeps it failing. Panics if `hist` does not actually fail.
pub fn minimize(kind: ModelKind, hist: &History) -> History {
    let msg = match check_history(kind, hist) {
        Err(f) => f.message,
        Ok(()) => panic!("minimize() called on a linearizable history"),
    };
    let (min, _msg) = shrink_to_fixed_point(
        hist.clone(),
        msg,
        |h| check_history(kind, h).map_err(|f| f.message),
        shrink_history,
        10_000,
    );
    min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::history::{Op, Ret};

    /// Event shorthand: (task, invoke, response, op, ret).
    fn ev(task: usize, invoke: u64, response: u64, op: Op, ret: Ret) -> Completed {
        Completed { task, invoke, response, op, ret }
    }

    #[test]
    fn empty_and_sequential_histories_pass() {
        assert!(check_history(ModelKind::Stack, &vec![]).is_ok());
        let h = vec![
            ev(0, 1, 2, Op::Push(5), Ret::Unit),
            ev(0, 3, 4, Op::Pop, Ret::Val(Some(5))),
            ev(0, 5, 6, Op::Pop, Ret::Val(None)),
        ];
        assert!(check_history(ModelKind::Stack, &h).is_ok());
    }

    #[test]
    fn sequential_wrong_return_fails() {
        let h = vec![
            ev(0, 1, 2, Op::Push(5), Ret::Unit),
            ev(0, 3, 4, Op::Pop, Ret::Val(Some(6))),
        ];
        let f = check_history(ModelKind::Stack, &h).unwrap_err();
        assert_eq!(f.chunk, (1, 1), "failure localized to the impossible pop");
    }

    #[test]
    fn overlap_allows_reordering() {
        // Pop overlaps the push whose value it returns: only the order
        // push-then-pop explains it, and the intervals permit it.
        let h = vec![
            ev(0, 1, 10, Op::Push(7), Ret::Unit),
            ev(1, 2, 9, Op::Pop, Ret::Val(Some(7))),
        ];
        assert!(check_history(ModelKind::Stack, &h).is_ok());
    }

    #[test]
    fn precedence_is_enforced() {
        // Same two ops, but the pop COMPLETES before the push is invoked:
        // no linearization can make the pop see the value.
        let h = vec![
            ev(1, 1, 2, Op::Pop, Ret::Val(Some(7))),
            ev(0, 3, 10, Op::Push(7), Ret::Unit),
        ];
        assert!(check_history(ModelKind::Stack, &h).is_err());
    }

    #[test]
    fn duplicate_pop_of_one_push_fails() {
        // The classic lost-update symptom: one push observed by two pops.
        let h = vec![
            ev(0, 1, 2, Op::Push(7), Ret::Unit),
            ev(1, 3, 6, Op::Pop, Ret::Val(Some(7))),
            ev(2, 4, 5, Op::Pop, Ret::Val(Some(7))),
        ];
        let f = check_history(ModelKind::Stack, &h).unwrap_err();
        assert!(f.window.len() >= 2);
    }

    #[test]
    fn ambiguity_resolved_across_chunks_by_backtracking() {
        // Two overlapping pushes (chunk 1) admit both orders; later pops
        // (chunk 2, disjoint) observe one — the DFS must be able to
        // revise its chunk-1 guess when chunk 2 refutes it. A checker
        // that committed to one order per chunk would flakily fail this.
        let h = vec![
            ev(0, 1, 10, Op::Push(1), Ret::Unit),
            ev(1, 2, 9, Op::Push(2), Ret::Unit),
            ev(0, 20, 21, Op::Pop, Ret::Val(Some(1))),
            ev(0, 22, 23, Op::Pop, Ret::Val(Some(2))),
            ev(0, 24, 25, Op::Pop, Ret::Val(None)),
        ];
        assert!(check_history(ModelKind::Stack, &h).is_ok());
        // And the mirror order also passes from the same prefix.
        let mut h2 = h.clone();
        h2[2].ret = Ret::Val(Some(2));
        h2[3].ret = Ret::Val(Some(1));
        assert!(check_history(ModelKind::Stack, &h2).is_ok());
        // But an order no interleaving explains does not.
        let mut h3 = h.clone();
        h3[3].ret = Ret::Val(Some(1)); // 1 popped twice
        assert!(check_history(ModelKind::Stack, &h3).is_err());
    }

    #[test]
    fn queue_fifo_violation_caught() {
        // Enq(1) strictly precedes Enq(2); dequeues observing 2 first
        // violate FIFO.
        let h = vec![
            ev(0, 1, 2, Op::Enq(1), Ret::Unit),
            ev(0, 3, 4, Op::Enq(2), Ret::Unit),
            ev(1, 5, 6, Op::Deq, Ret::Val(Some(2))),
            ev(1, 7, 8, Op::Deq, Ret::Val(Some(1))),
        ];
        assert!(check_history(ModelKind::Queue, &h).is_err());
        // Whereas with overlapping enqueues either order is fine.
        let h2 = vec![
            ev(0, 1, 10, Op::Enq(1), Ret::Unit),
            ev(2, 2, 9, Op::Enq(2), Ret::Unit),
            ev(1, 20, 21, Op::Deq, Ret::Val(Some(2))),
            ev(1, 22, 23, Op::Deq, Ret::Val(Some(1))),
        ];
        assert!(check_history(ModelKind::Queue, &h2).is_ok());
    }

    #[test]
    fn set_and_map_histories() {
        let h = vec![
            ev(0, 1, 2, Op::SetInsert(3), Ret::Bool(true)),
            ev(1, 3, 8, Op::SetInsert(3), Ret::Bool(false)),
            ev(2, 4, 7, Op::SetRemove(3), Ret::Bool(true)),
            ev(0, 9, 10, Op::SetContains(3), Ret::Bool(false)),
        ];
        assert!(check_history(ModelKind::Set, &h).is_ok());
        // Remove succeeding twice with one insert is impossible.
        let h2 = vec![
            ev(0, 1, 2, Op::SetInsert(3), Ret::Bool(true)),
            ev(1, 3, 6, Op::SetRemove(3), Ret::Bool(true)),
            ev(2, 4, 5, Op::SetRemove(3), Ret::Bool(true)),
        ];
        assert!(check_history(ModelKind::Set, &h2).is_err());

        let hm = vec![
            ev(0, 1, 6, Op::MapInsert(1, 10), Ret::Bool(true)),
            ev(1, 2, 5, Op::MapGet(1), Ret::Val(Some(10))),
            ev(2, 7, 8, Op::MapInsert(1, 99), Ret::Bool(false)),
            ev(2, 9, 10, Op::MapGet(1), Ret::Val(Some(10))),
        ];
        assert!(check_history(ModelKind::Map, &hm).is_ok());
        let mut hm2 = hm.clone();
        hm2[3].ret = Ret::Val(Some(99)); // the rejected insert must not clobber
        assert!(check_history(ModelKind::Map, &hm2).is_err());
    }

    #[test]
    fn chunk_ranges_split_on_quiescent_points() {
        let h = vec![
            ev(0, 1, 5, Op::Push(1), Ret::Unit),
            ev(1, 2, 6, Op::Push(2), Ret::Unit),
            ev(0, 7, 8, Op::Pop, Ret::Val(Some(2))),
            ev(0, 9, 12, Op::Pop, Ret::Val(Some(1))),
        ];
        assert_eq!(chunk_ranges(&h), vec![(0, 2), (2, 3), (3, 4)]);
    }

    #[test]
    fn ten_thousand_op_history_checks_fast() {
        // Mostly-sequential history with an overlap burst every fourth
        // event pair — the shape real recorded histories have. The
        // interval pruning must keep this fast (we assert a generous
        // bound so CI variance cannot flake the test).
        let mut h = Vec::new();
        let mut t = 0u64;
        for i in 0..2_500u64 {
            let (a, b) = (i * 2 + 1, i * 2 + 2);
            // Two overlapping pushes: both linearization orders are live
            // until the pops below commit to one.
            h.push(ev(0, t + 1, t + 4, Op::Push(a), Ret::Unit));
            h.push(ev(1, t + 2, t + 3, Op::Push(b), Ret::Unit));
            // Drain in an order only ONE of the two admits (b on top).
            h.push(ev(0, t + 5, t + 6, Op::Pop, Ret::Val(Some(b))));
            h.push(ev(0, t + 7, t + 8, Op::Pop, Ret::Val(Some(a))));
            t += 8;
        }
        let t0 = std::time::Instant::now();
        assert!(check_history(ModelKind::Stack, &h).is_ok());
        // Generous bound (tier-1 runs the debug profile on shared
        // runners): the point is to catch exponential blow-up, which
        // shows up as minutes or a 4M-state panic, not seconds.
        assert!(
            t0.elapsed().as_millis() < 15_000,
            "pruned check took {:?} for {} events",
            t0.elapsed(),
            h.len()
        );
    }

    #[test]
    fn minimize_reaches_a_small_fixed_point() {
        // Bury a 3-event duplicate-pop violation in 60 valid events.
        let mut h = Vec::new();
        let mut t = 100u64;
        for i in 0..30u64 {
            h.push(ev(0, t, t + 1, Op::Push(500 + i), Ret::Unit));
            h.push(ev(0, t + 2, t + 3, Op::Pop, Ret::Val(Some(500 + i))));
            t += 4;
        }
        h.push(ev(0, 1, 2, Op::Push(7), Ret::Unit));
        h.push(ev(1, 3, 6, Op::Pop, Ret::Val(Some(7))));
        h.push(ev(2, 4, 5, Op::Pop, Ret::Val(Some(7))));
        assert!(check_history(ModelKind::Stack, &h).is_err());
        let min = minimize(ModelKind::Stack, &h);
        assert!(check_history(ModelKind::Stack, &min).is_err(), "minimized still fails");
        assert!(min.len() <= 3, "fixed-point minimization should isolate the violation: {min:?}");
        // Fixed point: removing any further event makes it pass.
        for i in 0..min.len() {
            let mut m = min.clone();
            m.remove(i);
            assert!(check_history(ModelKind::Stack, &m).is_ok());
        }
    }
}
