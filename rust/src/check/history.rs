//! Concurrent histories: invoke/response events over the collection
//! operations, stamped by a shared virtual clock.
//!
//! A *history* in the Wing–Gong sense is a set of completed operations,
//! each carrying the interval `[invoke, response]` during which its
//! linearization point must fall. The [`HistoryRecorder`] produces such
//! histories from real concurrent tasks: it stamps `invoke` on a shared
//! [`VClock`](crate::sim::engine::VClock) immediately before the
//! operation runs and `response` immediately after, so interval
//! precedence (`response_a < invoke_b`) is sound evidence that operation
//! A really completed before B began. The DES mutation testbed
//! ([`crate::check::mutation`]) emits the same event type with virtual
//! times from the engine's heap instead.

use crate::sim::engine::{VClock, VTime};
use std::fmt;
use std::sync::{Arc, Mutex};

/// One operation against a checked collection. A single enum (rather than
/// one type per collection) keeps the checker monomorphic and histories
/// printable/serializable with no generics.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Op {
    /// Stack push.
    Push(u64),
    /// Stack pop.
    Pop,
    /// Queue enqueue.
    Enq(u64),
    /// Queue dequeue.
    Deq,
    /// Sorted-list (set) insert.
    SetInsert(u64),
    /// Sorted-list (set) remove.
    SetRemove(u64),
    /// Sorted-list (set) membership test.
    SetContains(u64),
    /// Hash-table insert (rejects duplicates, like the interlocked table).
    MapInsert(u64, u64),
    /// Hash-table remove.
    MapRemove(u64),
    /// Hash-table lookup.
    MapGet(u64),
}

/// The observed return value of an [`Op`].
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Ret {
    /// Operations with no observable return (push/enqueue).
    Unit,
    /// Boolean results (insert/remove/contains).
    Bool(bool),
    /// Optional-value results (pop/dequeue/get).
    Val(Option<u64>),
}

/// One completed operation: who ran it, when it was invoked and when it
/// responded (virtual time), what it did and what it observed.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Completed {
    pub task: usize,
    pub invoke: VTime,
    pub response: VTime,
    pub op: Op,
    pub ret: Ret,
}

impl fmt::Display for Completed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "task={} [{}, {}] {:?} -> {:?}",
            self.task, self.invoke, self.response, self.op, self.ret
        )
    }
}

/// A complete history (every invocation has its response).
pub type History = Vec<Completed>;

/// Render a history one event per line (the on-disk format the CLI writes
/// for CI artifacts — small, diffable, and enough to replay by hand).
pub fn render_history(hist: &History) -> String {
    let mut s = String::new();
    for e in hist {
        s.push_str(&e.to_string());
        s.push('\n');
    }
    s
}

/// Records completed operations from concurrently running tasks.
///
/// Cloneable handle; all clones feed one event log. `record` stamps the
/// interval around the closure on the shared clock, so the produced
/// intervals genuinely overlap when tasks genuinely overlap.
#[derive(Clone, Default)]
pub struct HistoryRecorder {
    clock: Arc<VClock>,
    events: Arc<Mutex<Vec<Completed>>>,
}

impl HistoryRecorder {
    pub fn new() -> HistoryRecorder {
        HistoryRecorder::default()
    }

    /// The shared clock (for callers that need extra stamps, e.g. the
    /// reclamation auditor tagging accesses onto the same timeline).
    pub fn clock(&self) -> &Arc<VClock> {
        &self.clock
    }

    /// Run `f` as operation `op` of `task`, recording its interval and
    /// observed return. Returns the closure's result unchanged.
    pub fn record(&self, task: usize, op: Op, f: impl FnOnce() -> Ret) -> Ret {
        let invoke = self.clock.stamp();
        let ret = f();
        let response = self.clock.stamp();
        self.events.lock().unwrap().push(Completed { task, invoke, response, op, ret });
        ret
    }

    /// Number of events recorded so far.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain the recorded history, sorted by invocation time.
    pub fn take(&self) -> History {
        let mut h = std::mem::take(&mut *self.events.lock().unwrap());
        h.sort_by_key(|e| e.invoke);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_preserves_interval_order() {
        let r = HistoryRecorder::new();
        r.record(0, Op::Push(1), || Ret::Unit);
        r.record(1, Op::Pop, || Ret::Val(Some(1)));
        let h = r.take();
        assert_eq!(h.len(), 2);
        assert!(h[0].invoke < h[0].response);
        assert!(h[0].response < h[1].invoke, "sequential ops get disjoint intervals");
        assert_eq!(h[0].op, Op::Push(1));
        assert_eq!(h[1].ret, Ret::Val(Some(1)));
        assert!(r.is_empty(), "take drains");
    }

    #[test]
    fn concurrent_records_overlap_and_all_arrive() {
        let r = HistoryRecorder::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let r = r.clone();
                s.spawn(move || {
                    for i in 0..250 {
                        r.record(t, Op::Push((t * 250 + i) as u64), || Ret::Unit);
                    }
                });
            }
        });
        let h = r.take();
        assert_eq!(h.len(), 1_000);
        // Sorted by invoke, stamps unique.
        assert!(h.windows(2).all(|w| w[0].invoke < w[1].invoke));
        for e in &h {
            assert!(e.invoke < e.response);
        }
    }

    #[test]
    fn render_is_line_per_event() {
        let r = HistoryRecorder::new();
        r.record(2, Op::MapInsert(7, 70), || Ret::Bool(true));
        let out = render_history(&r.take());
        assert_eq!(out.lines().count(), 1);
        assert!(out.contains("task=2"));
        assert!(out.contains("MapInsert(7, 70)"));
        assert!(out.contains("Bool(true)"));
    }
}
