//! Reclamation-safety auditing: a shadow state machine over every heap
//! object's lifecycle (`Live → Retired → Freed`) plus the pin sessions of
//! every epoch token, fed by hooks in [`crate::pgas::Pgas`] (alloc/free)
//! and [`crate::epoch::EpochManager`] (pin/unpin/retire/advance).
//!
//! The auditor flags exactly the failures distributed EBR exists to
//! prevent:
//!
//! * **Use-after-free** — an access (reported via
//!   [`ReclaimAudit::on_access`]) to an object already freed. Accessing
//!   a merely *retired* object is legal — that is the whole point of
//!   deferral. Only the DES mutation testbed reports accesses (the real
//!   collections' reads are not instrumented); on the real-collection
//!   path a free that could race a reader surfaces as **premature
//!   free** below, which is the root cause every use-after-free needs.
//! * **Double-free** — two frees of one object, or a retire of an object
//!   already freed (the retire would enqueue a second free).
//! * **Premature free** — the EBR safety invariant itself: a retired
//!   object may only be freed once every token that was **pinned at
//!   retire time** has since unpinned. Such a token could have read a
//!   reference to the object before its logical removal; freeing under
//!   it is the use-after-free window the epoch protocol closes. This is
//!   policy-independent (it holds for both `Conservative` and
//!   `PaperTwoStale`) and catches a quiescence scan or drain-ordering
//!   bug in the real manager, not just in mutants.
//!
//! Objects allocated before the auditor attached (sentinels, dummies)
//! are unknown to the shadow map and deliberately ignored. Address reuse
//! by the host allocator is handled by `on_alloc` resetting the slot.

use crate::pgas::WidePtr;
use std::collections::HashMap;
use std::sync::Mutex;

/// Hook surface the substrate calls when an auditor is attached. All
/// methods default to no-ops so the trait doubles as a marker for
/// "observability points the reclamation protocol exposes".
pub trait ReclaimAudit: Send + Sync {
    /// An object became live at `w`.
    fn on_alloc(&self, w: WidePtr) {
        let _ = w;
    }
    /// `defer_delete` retired `w` under `epoch`.
    fn on_retire(&self, w: WidePtr, epoch: u64) {
        let _ = (w, epoch);
    }
    /// The substrate freed `w` (reclamation drain, teardown, or a direct
    /// free of an unpublished object).
    fn on_free(&self, w: WidePtr) {
        let _ = w;
    }
    /// Token `token` pinned into `epoch` (transition from quiescent only;
    /// idempotent re-pins are not reported).
    fn on_pin(&self, token: usize, epoch: u64) {
        let _ = (token, epoch);
    }
    /// Token `token` became quiescent.
    fn on_unpin(&self, token: usize) {
        let _ = token;
    }
    /// The global epoch advanced to `new_epoch`.
    fn on_advance(&self, new_epoch: u64) {
        let _ = new_epoch;
    }
    /// Harness-visible access to (the memory behind) `w`.
    fn on_access(&self, w: WidePtr) {
        let _ = w;
    }
}

/// What went wrong.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ViolationKind {
    UseAfterFree,
    DoubleFree,
    PrematureFree,
}

#[derive(Clone, Debug)]
pub struct Violation {
    pub kind: ViolationKind,
    pub detail: String,
}

/// Aggregate event counts (sanity checks in tests: retires ≤ frees after
/// a clear, every pin matched, …).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct AuditCounts {
    pub allocs: u64,
    pub frees: u64,
    pub retires: u64,
    pub accesses: u64,
    pub pins: u64,
    pub unpins: u64,
    pub advances: u64,
}

#[derive(Clone, Debug)]
enum ObjState {
    Live,
    /// Retired in `epoch`; `readers` holds the pin sessions (token id,
    /// session generation) that were open at retire time.
    Retired { epoch: u64, readers: Vec<(usize, u64)> },
    Freed,
}

#[derive(Default)]
struct AuditState {
    objs: HashMap<(u16, u64), ObjState>,
    /// token id → generation of its currently-open pin session.
    pinned: HashMap<usize, u64>,
    next_gen: u64,
    violations: Vec<Violation>,
    counts: AuditCounts,
}

/// The concrete auditor. Attach one instance to a `Pgas` (and thereby to
/// every `EpochManager` on it) via [`crate::pgas::Pgas::set_audit`].
#[derive(Default)]
pub struct ReclaimAuditor {
    inner: Mutex<AuditState>,
}

impl ReclaimAuditor {
    pub fn new() -> ReclaimAuditor {
        ReclaimAuditor::default()
    }

    pub fn violations(&self) -> Vec<Violation> {
        self.inner.lock().unwrap().violations.clone()
    }

    /// True iff no violation has been observed.
    pub fn ok(&self) -> bool {
        self.inner.lock().unwrap().violations.is_empty()
    }

    pub fn counts(&self) -> AuditCounts {
        self.inner.lock().unwrap().counts
    }

    fn flag(st: &mut AuditState, kind: ViolationKind, detail: String) {
        st.violations.push(Violation { kind, detail });
    }

    #[inline]
    fn key(w: WidePtr) -> (u16, u64) {
        (w.locale.0, w.addr)
    }
}

impl ReclaimAudit for ReclaimAuditor {
    fn on_alloc(&self, w: WidePtr) {
        let mut st = self.inner.lock().unwrap();
        st.counts.allocs += 1;
        // Address reuse: a fresh allocation resets any prior lifecycle.
        st.objs.insert(Self::key(w), ObjState::Live);
    }

    fn on_retire(&self, w: WidePtr, epoch: u64) {
        let mut st = self.inner.lock().unwrap();
        st.counts.retires += 1;
        let readers: Vec<(usize, u64)> = st.pinned.iter().map(|(&t, &g)| (t, g)).collect();
        match st.objs.get(&Self::key(w)).cloned() {
            None => {} // pre-attach object: not tracked
            Some(ObjState::Live) => {
                st.objs.insert(Self::key(w), ObjState::Retired { epoch, readers });
            }
            Some(ObjState::Retired { .. }) => {
                Self::flag(&mut st, ViolationKind::DoubleFree, format!("double retire of {w:?}"));
            }
            Some(ObjState::Freed) => {
                Self::flag(
                    &mut st,
                    ViolationKind::DoubleFree,
                    format!("retire of already-freed {w:?}"),
                );
            }
        }
    }

    fn on_free(&self, w: WidePtr) {
        let mut st = self.inner.lock().unwrap();
        st.counts.frees += 1;
        match st.objs.get(&Self::key(w)).cloned() {
            None => {} // pre-attach object
            Some(ObjState::Live) => {
                // A direct free of a never-retired object is legal (an
                // unpublished speculative node, or teardown).
                st.objs.insert(Self::key(w), ObjState::Freed);
            }
            Some(ObjState::Retired { epoch, readers }) => {
                for (tok, gen) in readers {
                    if st.pinned.get(&tok) == Some(&gen) {
                        Self::flag(
                            &mut st,
                            ViolationKind::PrematureFree,
                            format!(
                                "{w:?} retired in epoch {epoch} freed while token {tok:#x} \
                                 is still inside the pin session open at retire time"
                            ),
                        );
                    }
                }
                st.objs.insert(Self::key(w), ObjState::Freed);
            }
            Some(ObjState::Freed) => {
                Self::flag(&mut st, ViolationKind::DoubleFree, format!("double free of {w:?}"));
            }
        }
    }

    fn on_pin(&self, token: usize, _epoch: u64) {
        let mut st = self.inner.lock().unwrap();
        st.counts.pins += 1;
        st.next_gen += 1;
        let gen = st.next_gen;
        st.pinned.insert(token, gen);
    }

    fn on_unpin(&self, token: usize) {
        let mut st = self.inner.lock().unwrap();
        st.counts.unpins += 1;
        st.pinned.remove(&token);
    }

    fn on_advance(&self, _new_epoch: u64) {
        self.inner.lock().unwrap().counts.advances += 1;
    }

    fn on_access(&self, w: WidePtr) {
        let mut st = self.inner.lock().unwrap();
        st.counts.accesses += 1;
        let freed = matches!(st.objs.get(&Self::key(w)), Some(ObjState::Freed));
        if freed {
            Self::flag(
                &mut st,
                ViolationKind::UseAfterFree,
                format!("access to freed object {w:?}"),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pgas::LocaleId;

    fn w(addr: u64) -> WidePtr {
        WidePtr::new(LocaleId(0), addr)
    }

    #[test]
    fn clean_lifecycle_is_clean() {
        let a = ReclaimAuditor::new();
        a.on_pin(1, 1);
        a.on_alloc(w(16));
        a.on_access(w(16));
        a.on_retire(w(16), 1);
        a.on_access(w(16)); // retired-but-not-freed access is LEGAL
        a.on_unpin(1);
        a.on_advance(2);
        a.on_free(w(16));
        assert!(a.ok(), "violations: {:?}", a.violations());
        let c = a.counts();
        assert_eq!((c.allocs, c.retires, c.frees, c.accesses), (1, 1, 1, 2));
    }

    #[test]
    fn use_after_free_flagged() {
        let a = ReclaimAuditor::new();
        a.on_alloc(w(16));
        a.on_free(w(16));
        a.on_access(w(16));
        let v = a.violations();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].kind, ViolationKind::UseAfterFree);
    }

    #[test]
    fn double_free_and_double_retire_flagged() {
        let a = ReclaimAuditor::new();
        a.on_alloc(w(16));
        a.on_free(w(16));
        a.on_free(w(16));
        assert_eq!(a.violations()[0].kind, ViolationKind::DoubleFree);

        let b = ReclaimAuditor::new();
        b.on_pin(9, 1);
        b.on_alloc(w(32));
        b.on_retire(w(32), 1);
        b.on_retire(w(32), 1);
        assert_eq!(b.violations()[0].kind, ViolationKind::DoubleFree);
    }

    #[test]
    fn premature_free_requires_the_retire_time_session() {
        // Token pinned at retire time and STILL pinned at free time: bug.
        let a = ReclaimAuditor::new();
        a.on_pin(7, 1);
        a.on_alloc(w(16));
        a.on_retire(w(16), 1);
        a.on_free(w(16));
        assert_eq!(a.violations()[0].kind, ViolationKind::PrematureFree);

        // Same token re-pinned in a NEW session: safe — the new session
        // began after the retire, so it cannot hold a stale reference.
        let b = ReclaimAuditor::new();
        b.on_pin(7, 1);
        b.on_alloc(w(16));
        b.on_retire(w(16), 1);
        b.on_unpin(7);
        b.on_pin(7, 2);
        b.on_free(w(16));
        assert!(b.ok(), "violations: {:?}", b.violations());
    }

    #[test]
    fn unknown_objects_ignored_and_reuse_resets() {
        let a = ReclaimAuditor::new();
        // Sentinel allocated before attach: free + access are ignored.
        a.on_free(w(48));
        a.on_access(w(48));
        assert!(a.ok());
        // Reuse: alloc at a previously-freed address starts a new life.
        a.on_alloc(w(16));
        a.on_free(w(16));
        a.on_alloc(w(16));
        a.on_access(w(16));
        a.on_free(w(16));
        assert!(a.ok(), "violations: {:?}", a.violations());
    }
}
