//! Sequential specifications the linearizability checker replays
//! histories against: `Vec` for the stack, `VecDeque` for the queue,
//! `BTreeSet` for the sorted list, `BTreeMap` for the hash table.
//!
//! A checked collection is linearizable iff its concurrent history can be
//! reordered (respecting interval precedence) into a sequence that this
//! model reproduces return-for-return.

use super::history::{Op, Ret};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Which collection a history is checked against.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Stack,
    Queue,
    Set,
    Map,
}

impl ModelKind {
    pub fn label(self) -> &'static str {
        match self {
            ModelKind::Stack => "stack",
            ModelKind::Queue => "queue",
            ModelKind::Set => "list",
            ModelKind::Map => "map",
        }
    }
}

/// The sequential model state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SeqModel {
    Stack(Vec<u64>),
    Queue(VecDeque<u64>),
    Set(BTreeSet<u64>),
    Map(BTreeMap<u64, u64>),
}

impl SeqModel {
    pub fn new(kind: ModelKind) -> SeqModel {
        match kind {
            ModelKind::Stack => SeqModel::Stack(Vec::new()),
            ModelKind::Queue => SeqModel::Queue(VecDeque::new()),
            ModelKind::Set => SeqModel::Set(BTreeSet::new()),
            ModelKind::Map => SeqModel::Map(BTreeMap::new()),
        }
    }

    /// Apply `op` sequentially, returning the specified result. Panics on
    /// an op that does not belong to this model (a harness bug, not a
    /// checkable outcome).
    pub fn apply(&mut self, op: &Op) -> Ret {
        match (self, op) {
            (SeqModel::Stack(s), Op::Push(v)) => {
                s.push(*v);
                Ret::Unit
            }
            (SeqModel::Stack(s), Op::Pop) => Ret::Val(s.pop()),
            (SeqModel::Queue(q), Op::Enq(v)) => {
                q.push_back(*v);
                Ret::Unit
            }
            (SeqModel::Queue(q), Op::Deq) => Ret::Val(q.pop_front()),
            (SeqModel::Set(s), Op::SetInsert(k)) => Ret::Bool(s.insert(*k)),
            (SeqModel::Set(s), Op::SetRemove(k)) => Ret::Bool(s.remove(k)),
            (SeqModel::Set(s), Op::SetContains(k)) => Ret::Bool(s.contains(k)),
            // Like the interlocked table: insert REJECTS an existing key
            // (no overwrite), remove reports presence, get clones.
            (SeqModel::Map(m), Op::MapInsert(k, v)) => {
                if m.contains_key(k) {
                    Ret::Bool(false)
                } else {
                    m.insert(*k, *v);
                    Ret::Bool(true)
                }
            }
            (SeqModel::Map(m), Op::MapRemove(k)) => Ret::Bool(m.remove(k).is_some()),
            (SeqModel::Map(m), Op::MapGet(k)) => Ret::Val(m.get(k).copied()),
            (model, op) => panic!("op {op:?} does not fit model {model:?}"),
        }
    }

    /// A canonical serialization of the state, used as (half of) the
    /// memoization key in the checker's DFS. Exact — two states share a
    /// canon iff they are equal — so memoization can never mask a real
    /// linearization.
    pub fn canon(&self) -> Vec<u64> {
        match self {
            SeqModel::Stack(s) => s.clone(),
            SeqModel::Queue(q) => q.iter().copied().collect(),
            SeqModel::Set(s) => s.iter().copied().collect(),
            SeqModel::Map(m) => m.iter().flat_map(|(&k, &v)| [k, v]).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_lifo() {
        let mut m = SeqModel::new(ModelKind::Stack);
        assert_eq!(m.apply(&Op::Push(1)), Ret::Unit);
        assert_eq!(m.apply(&Op::Push(2)), Ret::Unit);
        assert_eq!(m.apply(&Op::Pop), Ret::Val(Some(2)));
        assert_eq!(m.apply(&Op::Pop), Ret::Val(Some(1)));
        assert_eq!(m.apply(&Op::Pop), Ret::Val(None));
    }

    #[test]
    fn queue_fifo() {
        let mut m = SeqModel::new(ModelKind::Queue);
        m.apply(&Op::Enq(1));
        m.apply(&Op::Enq(2));
        assert_eq!(m.apply(&Op::Deq), Ret::Val(Some(1)));
        assert_eq!(m.apply(&Op::Deq), Ret::Val(Some(2)));
        assert_eq!(m.apply(&Op::Deq), Ret::Val(None));
    }

    #[test]
    fn set_semantics() {
        let mut m = SeqModel::new(ModelKind::Set);
        assert_eq!(m.apply(&Op::SetInsert(5)), Ret::Bool(true));
        assert_eq!(m.apply(&Op::SetInsert(5)), Ret::Bool(false));
        assert_eq!(m.apply(&Op::SetContains(5)), Ret::Bool(true));
        assert_eq!(m.apply(&Op::SetRemove(5)), Ret::Bool(true));
        assert_eq!(m.apply(&Op::SetRemove(5)), Ret::Bool(false));
        assert_eq!(m.apply(&Op::SetContains(5)), Ret::Bool(false));
    }

    #[test]
    fn map_insert_rejects_duplicates_like_the_table() {
        let mut m = SeqModel::new(ModelKind::Map);
        assert_eq!(m.apply(&Op::MapInsert(1, 10)), Ret::Bool(true));
        assert_eq!(m.apply(&Op::MapInsert(1, 99)), Ret::Bool(false));
        assert_eq!(m.apply(&Op::MapGet(1)), Ret::Val(Some(10)), "duplicate must not clobber");
        assert_eq!(m.apply(&Op::MapRemove(1)), Ret::Bool(true));
        assert_eq!(m.apply(&Op::MapGet(1)), Ret::Val(None));
    }

    #[test]
    fn canon_distinguishes_order_sensitive_states() {
        let mut a = SeqModel::new(ModelKind::Stack);
        let mut b = SeqModel::new(ModelKind::Stack);
        a.apply(&Op::Push(1));
        a.apply(&Op::Push(2));
        b.apply(&Op::Push(2));
        b.apply(&Op::Push(1));
        assert_ne!(a.canon(), b.canon());
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn mismatched_op_panics() {
        SeqModel::new(ModelKind::Stack).apply(&Op::Deq);
    }
}
