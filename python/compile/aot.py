"""AOT bridge: lower the L2 graph to HLO *text* artifacts for the Rust
runtime.

HLO text — NOT a serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts]

Emits one artifact per supported shape plus a manifest the Rust side
reads to pick/pad buffers:

  reclaim_scan_L{L}xT{T}_N{N}.hlo.txt
  manifest.json
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import reclaim_scan

# Shapes compiled ahead of time: (locales, max_tokens_per_locale, owners_pad).
# Rust pads its inputs up to the smallest artifact that fits.
SHAPES = [
    (8, 16, 512),    # small: unit tests, quickstart example
    (64, 64, 4096),  # the paper's testbed: 64-locale XC-50
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(locales: int, tokens: int, owners_pad: int) -> str:
    epochs = jax.ShapeDtypeStruct((locales, tokens), jnp.int32)
    ge = jax.ShapeDtypeStruct((), jnp.int32)
    owners = jax.ShapeDtypeStruct((owners_pad,), jnp.int32)
    lowered = jax.jit(reclaim_scan).lower(epochs, ge, owners)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"artifacts": []}
    for locales, tokens, owners_pad in SHAPES:
        name = f"reclaim_scan_L{locales}xT{tokens}_N{owners_pad}.hlo.txt"
        path = os.path.join(out_dir, name)
        text = lower_one(locales, tokens, owners_pad)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "kind": "reclaim_scan",
                "locales": locales,
                "tokens": tokens,
                "owners_pad": owners_pad,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
