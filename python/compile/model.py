"""L2: the jax compute graph the Rust coordinator executes via PJRT.

``reclaim_scan`` composes the two L1 Pallas kernels into the decision the
elected tryReclaim task needs: *is it safe to advance* (plus the stale
breakdown for diagnostics) and *how large is each locale's bulk-free
transfer*. Python runs only at build time — ``aot.py`` lowers this
function once to HLO text; the request path is pure Rust.
"""

import jax.numpy as jnp

from .kernels.epoch_scan import epoch_scan
from .kernels.scatter_hist import scatter_hist


def reclaim_scan(epochs, global_epoch, owners):
    """The reclamation-scan graph.

    Args:
      epochs: i32[L, T] token-epoch table (0 = quiescent / padding).
      global_epoch: i32[] scalar current epoch.
      owners: i32[N] owner locale per drained object (-1 padding).

    Returns:
      (safe, stale, hist):
        safe: i32[] 1 iff no token is pinned in a previous epoch;
        stale: i32[L] stale-token count per locale;
        hist: i32[L] scatter-list sizes per destination locale.
    """
    locales = epochs.shape[0]
    stale = epoch_scan(epochs, global_epoch)
    safe = (jnp.sum(stale) == 0).astype(jnp.int32)
    hist = scatter_hist(owners, locales)
    return safe, stale, hist
