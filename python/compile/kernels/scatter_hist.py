"""L1 Pallas kernel: the scatter-list histogram.

tryReclaim sorts drained objects by owning locale before bulk-freeing
(Listing 4's ``objsToDelete[obj.locale.id].append(obj)``). Sizing those
per-destination transfers is a histogram over the owner array — computed
here as a tiled one-hot reduction, accumulating into the same (1, L)
output block across grid steps (the canonical Pallas accumulation
pattern).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(owners_ref, hist_ref):
    step = pl.program_id(0)
    o = owners_ref[...]  # (1, TILE) i32
    locales = hist_ref.shape[1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (o.shape[1], locales), 1)
    onehot = jnp.logical_and(o[0, :, None] == lanes, o[0, :, None] >= 0)
    partial = jnp.sum(onehot.astype(jnp.int32), axis=0, keepdims=True)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = partial

    @pl.when(step != 0)
    def _acc():
        hist_ref[...] += partial


def scatter_hist(owners, num_locales, tile=512):
    """Pallas version of :func:`..kernels.ref.scatter_hist_ref`.

    Args:
      owners: i32[N] owner locale per object, -1 padding. N must be a
        multiple of ``tile`` (the AOT wrapper pads).
      num_locales: static destination count L.

    Returns:
      counts: i32[L].
    """
    n = owners.shape[0]
    assert n % tile == 0, f"N={n} not a multiple of tile={tile}"
    o2 = jnp.reshape(owners.astype(jnp.int32), (1, n))
    hist = pl.pallas_call(
        _hist_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, num_locales), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, num_locales), jnp.int32),
        interpret=True,
    )(o2)
    return jnp.reshape(hist, (num_locales,))
