"""L1 Pallas kernel: the tryReclaim quiescence scan.

One grid step per locale: the (1, T) tile of token epochs is staged into
VMEM, compared against the (broadcast) global epoch, and reduced to that
locale's stale-token count. This is the data-parallel heart of Listing 4's
``coforall loc ... for tok in allocated_list`` loop.

TPU adaptation note (DESIGN.md §Hardware-Adaptation): the paper's scan is
a pointer-chase per locale; on an accelerator we lay the token table out
as a dense [L, T] i32 matrix (0-padded), tile it by locale so each block
fits VMEM, and use the VPU for the masked reduction — the MXU is not
involved. interpret=True everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(ge_ref, epochs_ref, stale_ref):
    """One locale's tile: stale count = #(e != 0 and e != global)."""
    e = epochs_ref[...]  # (1, T) i32
    ge = ge_ref[0, 0]
    bad = jnp.logical_and(e != 0, e != ge)
    stale_ref[...] = jnp.sum(bad.astype(jnp.int32), axis=1, keepdims=True)


def epoch_scan(epochs, global_epoch):
    """Pallas version of :func:`..kernels.ref.epoch_scan_ref`.

    Args:
      epochs: i32[L, T] token-epoch table (0 = quiescent/padding).
      global_epoch: i32[] scalar.

    Returns:
      stale: i32[L].
    """
    locales, tokens = epochs.shape
    ge = jnp.reshape(global_epoch.astype(jnp.int32), (1, 1))
    stale = pl.pallas_call(
        _scan_kernel,
        grid=(locales,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),       # global epoch, replicated
            pl.BlockSpec((1, tokens), lambda i: (i, 0)),  # locale i's token tile
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((locales, 1), jnp.int32),
        interpret=True,
    )(ge, epochs.astype(jnp.int32))
    return jnp.reshape(stale, (locales,))
