"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: pytest asserts the Pallas kernels
(interpret=True) match these references bit-exactly across shape sweeps.
"""

import jax.numpy as jnp


def epoch_scan_ref(epochs, global_epoch):
    """Quiescence scan (tryReclaim, Listing 4 lines 10-21).

    Args:
      epochs: i32[L, T] token epochs per locale; 0 = quiescent / padding.
      global_epoch: i32[] the current global epoch.

    Returns:
      stale: i32[L] — per-locale count of tokens pinned in a *different*
        epoch than ``global_epoch`` (nonzero anywhere => unsafe to advance).
    """
    epochs = epochs.astype(jnp.int32)
    bad = jnp.logical_and(epochs != 0, epochs != global_epoch)
    return jnp.sum(bad.astype(jnp.int32), axis=1)


def scatter_hist_ref(owners, num_locales):
    """Scatter-list histogram (tryReclaim, Listing 4 lines 33-43).

    Args:
      owners: i32[N] owning locale of each drained object; -1 = padding.
      num_locales: static L.

    Returns:
      counts: i32[L] — objects bound for each destination locale, i.e. the
        sizes of the per-locale bulk-free transfers.
    """
    owners = owners.astype(jnp.int32)
    onehot = owners[:, None] == jnp.arange(num_locales, dtype=jnp.int32)[None, :]
    valid = (owners >= 0)[:, None]
    return jnp.sum(jnp.logical_and(onehot, valid).astype(jnp.int32), axis=0)


def reclaim_scan_ref(epochs, global_epoch, owners):
    """The full L2 graph: scan + histogram + derived scalars."""
    stale = epoch_scan_ref(epochs, global_epoch)
    safe = (jnp.sum(stale) == 0).astype(jnp.int32)
    hist = scatter_hist_ref(owners, epochs.shape[0])
    return safe, stale, hist
