"""AOT path tests: lowering must produce parseable HLO text with the
expected I/O signature, and the manifest must describe every artifact."""

import json
import os
import subprocess
import sys

import pytest

from compile.aot import lower_one, SHAPES


def test_lower_small_shape_produces_hlo_text():
    text = lower_one(8, 16, 512)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Three outputs (safe, stale, hist) => a tuple root.
    assert "tuple" in text


@pytest.mark.parametrize("locales,tokens,owners_pad", SHAPES)
def test_lower_all_manifest_shapes(locales, tokens, owners_pad):
    text = lower_one(locales, tokens, owners_pad)
    # Input parameter shapes appear in the HLO signature.
    assert f"s32[{locales},{tokens}]" in text
    assert f"s32[{owners_pad}]" in text


def test_aot_main_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest["artifacts"]) == len(SHAPES)
    for a in manifest["artifacts"]:
        f = out / a["name"]
        assert f.exists(), a["name"]
        assert "HloModule" in f.read_text()[:200]
