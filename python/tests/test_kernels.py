"""Kernel-vs-reference equivalence: the build-time correctness gate.

Sweeps shapes, values and padding patterns (hypothesis-style, but with an
explicit seeded generator — the image has no hypothesis wheel) and checks
the Pallas kernels bit-exactly against the pure-jnp oracles.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels.epoch_scan import epoch_scan
from compile.kernels.ref import epoch_scan_ref, reclaim_scan_ref, scatter_hist_ref
from compile.kernels.scatter_hist import scatter_hist
from compile.model import reclaim_scan

RNG = np.random.default_rng(0xC0FFEE)


# ---------------------------------------------------------------- epoch_scan

SCAN_SHAPES = [(1, 8), (2, 16), (8, 16), (7, 33), (64, 64), (16, 128)]


@pytest.mark.parametrize("locales,tokens", SCAN_SHAPES)
def test_epoch_scan_matches_ref_random(locales, tokens):
    for ge in (1, 2, 3):
        epochs = RNG.integers(0, 4, size=(locales, tokens)).astype(np.int32)
        got = epoch_scan(jnp.asarray(epochs), jnp.int32(ge))
        want = epoch_scan_ref(jnp.asarray(epochs), jnp.int32(ge))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_epoch_scan_all_quiescent_is_clean():
    epochs = jnp.zeros((8, 16), jnp.int32)
    stale = epoch_scan(epochs, jnp.int32(2))
    assert int(jnp.sum(stale)) == 0


def test_epoch_scan_all_current_epoch_is_clean():
    epochs = jnp.full((4, 8), 3, jnp.int32)
    stale = epoch_scan(epochs, jnp.int32(3))
    assert int(jnp.sum(stale)) == 0


def test_epoch_scan_single_stale_token_detected():
    epochs = np.zeros((8, 16), np.int32)
    epochs[5, 7] = 1  # pinned in epoch 1
    stale = np.asarray(epoch_scan(jnp.asarray(epochs), jnp.int32(2)))
    assert stale[5] == 1
    assert stale.sum() == 1


def test_epoch_scan_counts_multiple_stale_per_locale():
    epochs = np.zeros((2, 8), np.int32)
    epochs[1, :4] = 1
    epochs[1, 4:] = 2  # current
    stale = np.asarray(epoch_scan(jnp.asarray(epochs), jnp.int32(2)))
    assert list(stale) == [0, 4]


# -------------------------------------------------------------- scatter_hist

HIST_SHAPES = [(512, 2), (512, 8), (1024, 64), (4096, 64), (2048, 7)]


@pytest.mark.parametrize("n,locales", HIST_SHAPES)
def test_scatter_hist_matches_ref_random(n, locales):
    owners = RNG.integers(-1, locales, size=n).astype(np.int32)
    got = scatter_hist(jnp.asarray(owners), locales)
    want = scatter_hist_ref(jnp.asarray(owners), locales)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_scatter_hist_all_padding_is_zero():
    owners = jnp.full((512,), -1, jnp.int32)
    hist = scatter_hist(owners, 8)
    assert int(jnp.sum(hist)) == 0


def test_scatter_hist_counts_exact():
    owners = np.full(512, -1, np.int32)
    owners[:10] = 3
    owners[10:15] = 0
    hist = np.asarray(scatter_hist(jnp.asarray(owners), 4))
    assert list(hist) == [5, 0, 0, 10]


def test_scatter_hist_multi_tile_accumulates():
    # Spans 4 tiles of 512: accumulation across grid steps must be exact.
    owners = np.zeros(2048, np.int32)  # everything owned by locale 0
    hist = np.asarray(scatter_hist(jnp.asarray(owners), 4))
    assert hist[0] == 2048


def test_scatter_hist_rejects_unaligned():
    with pytest.raises(AssertionError):
        scatter_hist(jnp.zeros((100,), jnp.int32), 4)


# ----------------------------------------------------------------- L2 graph

def test_reclaim_scan_matches_ref_sweep():
    for locales, tokens, n in [(8, 16, 512), (64, 64, 4096)]:
        epochs = RNG.integers(0, 4, size=(locales, tokens)).astype(np.int32)
        owners = RNG.integers(-1, locales, size=n).astype(np.int32)
        for ge in (1, 2, 3):
            safe, stale, hist = reclaim_scan(jnp.asarray(epochs), jnp.int32(ge), jnp.asarray(owners))
            rsafe, rstale, rhist = reclaim_scan_ref(jnp.asarray(epochs), jnp.int32(ge), jnp.asarray(owners))
            assert int(safe) == int(rsafe)
            np.testing.assert_array_equal(np.asarray(stale), np.asarray(rstale))
            np.testing.assert_array_equal(np.asarray(hist), np.asarray(rhist))


def test_reclaim_scan_safe_iff_no_stale():
    epochs = np.zeros((8, 16), np.int32)
    owners = np.full(512, -1, np.int32)
    safe, _, _ = reclaim_scan(jnp.asarray(epochs), jnp.int32(1), jnp.asarray(owners))
    assert int(safe) == 1
    epochs[0, 0] = 3  # stale vs ge=1
    safe, stale, _ = reclaim_scan(jnp.asarray(epochs), jnp.int32(1), jnp.asarray(owners))
    assert int(safe) == 0
    assert int(np.asarray(stale).sum()) == 1
