//! End-to-end driver: proves all layers compose.
//!
//! 1. Boots an 8-locale PGAS job on the **real substrate** (L3).
//! 2. Loads the **AOT-compiled reclaim-scan artifact** (L2/L1, built by
//!    `make artifacts` from the jax+Pallas sources) and attaches it to the
//!    EpochManager, so the PJRT executable sits on the reclamation path.
//! 3. Runs a mixed stack + queue + hash-table workload with EBR churn
//!    from every locale, recording per-op latency histograms.
//! 4. Replays the paper's Fig-4 sweep on the DES testbed for the
//!    scaling picture the single-core host cannot produce in wall clock.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use pgas_nb::collections::{InterlockedHashTable, LockFreeQueue, LockFreeStack};
use pgas_nb::coordinator::figures::{fig4, Scale};
use pgas_nb::epoch::EpochManager;
use pgas_nb::pgas::{coforall_locales, coforall_tasks, Machine, NicModel, Pgas};
use pgas_nb::runtime::SharedReclaimScan;
use pgas_nb::util::cli::Args;
use pgas_nb::util::stats::LatencyHistogram;
use pgas_nb::util::table::{fmt_nanos, fmt_ops, Table};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let locales = args.get_usize("locales", 8);
    let tasks = args.get_usize("tasks", 2);
    let ops = args.get_usize("ops", 10_000);

    println!("=== end-to-end: all three layers composed ===\n");

    // --- L3: boot the PGAS job -----------------------------------------
    let pgas = Pgas::new(Machine::new(locales, tasks), NicModel::aries_no_network_atomics());
    let em = EpochManager::new(Arc::clone(&pgas));

    // --- L2/L1: attach the PJRT reclaim-scan artifact -------------------
    let artifacts = args.get_or("artifacts", "artifacts");
    match SharedReclaimScan::load_fitting(artifacts, locales, 64, 4096) {
        Ok(scanner) => {
            println!("loaded PJRT reclaim-scan artifact: shape {:?}", scanner.shape());
            em.set_scanner(scanner).ok().expect("fresh manager");
            em.try_reclaim(); // warm the executable (first run pays lazy init)
        }
        Err(e) => {
            eprintln!("WARNING: no artifact ({e}); falling back to scalar scan.");
            eprintln!("         run `make artifacts` for the full three-layer path.");
        }
    }

    // --- workload --------------------------------------------------------
    let stack: LockFreeStack<u64> = LockFreeStack::new(Arc::clone(&pgas), em.clone());
    let queue: LockFreeQueue<u64> = LockFreeQueue::new(Arc::clone(&pgas), em.clone());
    let table: InterlockedHashTable<u64> =
        InterlockedHashTable::new(Arc::clone(&pgas), em.clone(), locales * 32);

    let op_hist = Mutex::new(LatencyHistogram::new());
    let reclaim_hist = Mutex::new(LatencyHistogram::new());
    let op_count = AtomicU64::new(0);
    let t0 = Instant::now();
    coforall_locales(pgas.machine(), |loc| {
        coforall_tasks(tasks, |tid| {
            let tok = em.register();
            let mut rng =
                pgas_nb::util::rng::Xoshiro256pp::new((loc.index() * tasks + tid) as u64 + 7);
            let mut local_hist = LatencyHistogram::new();
            let mut local_reclaims = LatencyHistogram::new();
            for i in 0..ops {
                let k = 1 + rng.next_below(2048);
                let t = Instant::now();
                match rng.next_below(8) {
                    0 => stack.push(&tok, k),
                    1 => {
                        stack.pop(&tok);
                    }
                    2 => queue.enqueue(&tok, k),
                    3 => {
                        queue.dequeue(&tok);
                    }
                    4..=5 => {
                        table.insert(&tok, k, k);
                    }
                    6 => {
                        table.remove(&tok, k);
                    }
                    _ => {
                        if let Some(v) = table.get(&tok, k) {
                            assert_eq!(v, k);
                        }
                    }
                }
                local_hist.record(t.elapsed().as_nanos() as u64);
                if i % 1024 == 0 {
                    let t = Instant::now();
                    tok.try_reclaim(); // PJRT kernel scan runs in here
                    local_reclaims.record(t.elapsed().as_nanos() as u64);
                }
            }
            op_count.fetch_add(ops as u64, Ordering::Relaxed);
            op_hist.lock().unwrap().merge(&local_hist);
            reclaim_hist.lock().unwrap().merge(&local_reclaims);
        });
    });
    let wall = t0.elapsed();

    // --- teardown + invariants ------------------------------------------
    {
        let tok = em.register();
        stack.drain(&tok);
        while queue.dequeue(&tok).is_some() {}
    }
    // Drop the structures (frees their remaining nodes), then reclaim all
    // deferred retirements.
    drop(stack);
    drop(queue);
    drop(table);
    em.clear();
    let s = em.stats();
    assert_eq!(s.deferred, s.freed, "reclamation must balance");
    assert_eq!(pgas.live_objects(), 0, "no leaks after teardown");

    // --- report -----------------------------------------------------------
    let oh = op_hist.into_inner().unwrap();
    let rh = reclaim_hist.into_inner().unwrap();
    let comm = pgas.comm_totals();
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["locales x tasks".into(), format!("{locales} x {tasks}")]);
    t.row(&["total ops".into(), op_count.load(Ordering::Relaxed).to_string()]);
    t.row(&["wall time".into(), format!("{wall:.2?}")]);
    t.row(&["throughput".into(), format!(
        "{} ops/s",
        fmt_ops(op_count.load(Ordering::Relaxed) as f64 / wall.as_secs_f64())
    )]);
    t.row(&["op latency p50/p95/p99".into(), format!(
        "{} / {} / {}",
        fmt_nanos(oh.percentile(50.0) as f64),
        fmt_nanos(oh.percentile(95.0) as f64),
        fmt_nanos(oh.percentile(99.0) as f64)
    )]);
    t.row(&["tryReclaim latency p50/p99".into(), format!(
        "{} / {}",
        fmt_nanos(rh.percentile(50.0) as f64),
        fmt_nanos(rh.percentile(99.0) as f64)
    )]);
    t.row(&["kernel scan attached".into(), em.has_scanner().to_string()]);
    t.row(&["epoch advances".into(), s.advances.to_string()]);
    t.row(&["objects deferred/freed".into(), format!("{}/{}", s.deferred, s.freed)]);
    t.row(&["remote frees".into(), s.freed_remote.to_string()]);
    t.row(&["comm: atomics/AMs/GETs".into(), format!(
        "{}/{}/{}",
        comm.atomics_local + comm.atomics_rdma,
        comm.ams,
        comm.gets
    )]);
    t.row(&["modeled comm time".into(), format!("{:.2} ms", comm.virtual_ns as f64 / 1e6)]);
    println!("\n{}", t.render());

    // --- DES replay of the paper's Fig 4 ---------------------------------
    println!("=== DES testbed replay: Fig 4 (deletion, tryReclaim/1024) ===");
    let scale = if args.flag("full") { Scale::Full } else { Scale::Quick };
    println!("{}", fig4(scale).render());
    println!("end_to_end OK");
}
