//! Quickstart: the two building blocks in ~60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pgas_nb::atomics::AtomicObject;
use pgas_nb::epoch::EpochManager;
use pgas_nb::pgas::{GlobalPtr, LocaleId, Machine, NicModel, Pgas};
use std::sync::Arc;

fn main() {
    // A 4-locale PGAS job on the Aries model without network atomics.
    let pgas = Pgas::new(Machine::new(4, 2), NicModel::aries_no_network_atomics());

    // --- AtomicObject: atomics on object references -------------------
    // Allocate an object on locale 2; the wide pointer carries locality.
    let obj = pgas.alloc(LocaleId(2), String::from("hello pgas"));
    let atom: AtomicObject<String> = AtomicObject::new(Arc::clone(&pgas), LocaleId(0));
    atom.write(obj);
    let seen = atom.read();
    assert_eq!(seen.locale(), LocaleId(2), "locality survives compression");
    println!("AtomicObject read back {:?} -> {}", seen.locale(), unsafe { seen.deref() });

    // ABA-protected compare-and-swap: the counter defeats A->B->A.
    let other = pgas.alloc(LocaleId(1), String::from("other"));
    let snapshot = atom.read_aba();
    atom.write_aba(other);
    atom.write_aba(obj); // back to the original pointer...
    assert!(!atom.compare_and_swap_aba(snapshot, other), "...but the ABA CAS still fails");
    println!("ABA protection detected the A->B->A excursion");

    // --- EpochManager: concurrent-safe deferred reclamation -----------
    let em = EpochManager::new(Arc::clone(&pgas));
    let tok = em.register(); // paper: tok = em.register(); RAII unregister
    tok.pin();
    tok.defer_delete(obj); // logically removed; physically freed later
    tok.defer_delete(other);
    tok.unpin();
    assert_eq!(pgas.live_objects(), 2, "deferred, not yet freed");

    // Advance the epoch until the grace period elapses.
    while pgas.live_objects() > 0 {
        assert!(em.try_reclaim().advanced());
    }
    println!("epoch advanced; deferred objects reclaimed safely");

    let s = em.stats();
    println!(
        "stats: advances={} deferred={} freed={} (remote={})",
        s.advances, s.deferred, s.freed, s.freed_remote
    );
    let _: GlobalPtr<String> = atom.exchange(GlobalPtr::nil());
    println!("quickstart OK");
}
