//! The paper's running example at work: a distributed Treiber stack under
//! concurrent churn from every locale, with epoch-based reclamation and a
//! periodic `tryReclaim`, reporting throughput and reclamation stats.
//!
//! ```bash
//! cargo run --release --example lockfree_stack -- --locales 4 --tasks 2 --ops 20000
//! ```

use pgas_nb::collections::LockFreeStack;
use pgas_nb::epoch::EpochManager;
use pgas_nb::pgas::{coforall_locales, coforall_tasks, Machine, NicModel, Pgas};
use pgas_nb::util::cli::Args;
use pgas_nb::util::rng::Xoshiro256pp;
use pgas_nb::util::table::fmt_ops;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let locales = args.get_usize("locales", 4);
    let tasks = args.get_usize("tasks", 2);
    let ops = args.get_usize("ops", 20_000);

    let pgas = Pgas::new(Machine::new(locales, tasks), NicModel::aries_no_network_atomics());
    let em = EpochManager::new(Arc::clone(&pgas));
    let stack: LockFreeStack<u64> = LockFreeStack::new(Arc::clone(&pgas), em.clone());

    let pushes = AtomicU64::new(0);
    let pops = AtomicU64::new(0);
    let t0 = Instant::now();
    coforall_locales(pgas.machine(), |loc| {
        coforall_tasks(tasks, |tid| {
            let tok = stack.register();
            let mut rng = Xoshiro256pp::new((loc.index() * tasks + tid) as u64 + 1);
            let (mut my_pushes, mut my_pops) = (0u64, 0u64);
            for i in 0..ops {
                if rng.chance(0.55) {
                    stack.push(&tok, (loc.index() * tasks + tid) as u64 * ops as u64 + i as u64);
                    my_pushes += 1;
                } else if stack.pop(&tok).is_some() {
                    my_pops += 1;
                }
                if i % 1024 == 0 {
                    tok.try_reclaim(); // Fig 4's cadence
                }
            }
            pushes.fetch_add(my_pushes, Ordering::Relaxed);
            pops.fetch_add(my_pops, Ordering::Relaxed);
        });
    });
    let wall = t0.elapsed();

    // Drain and verify conservation, then reclaim everything.
    let tok = stack.register();
    let drained = stack.drain(&tok) as u64;
    drop(tok);
    em.clear();

    let (pu, po) = (pushes.load(Ordering::Relaxed), pops.load(Ordering::Relaxed));
    assert_eq!(pu, po + drained, "push/pop conservation");
    let s = em.stats();
    assert_eq!(s.deferred, s.freed, "every retired node reclaimed");
    assert_eq!(pgas.live_objects(), 0, "no leaks");

    let total = (locales * tasks * ops) as f64;
    println!("lockfree_stack: {locales} locales x {tasks} tasks x {ops} ops in {wall:.2?}");
    println!("  throughput      {} ops/s (wall, single host core)", fmt_ops(total / wall.as_secs_f64()));
    println!("  pushes/pops     {pu}/{po} (+{drained} drained)");
    println!("  epoch advances  {} (not-quiescent aborts: {})", s.advances, s.not_quiescent);
    println!("  nodes reclaimed {} ({} on remote locales)", s.freed, s.freed_remote);
    let comm = pgas.comm_totals();
    println!("  comm volume     {} atomics, {} AMs, {:.1} KiB payload",
        comm.atomics_local + comm.atomics_rdma, comm.ams, comm.bytes as f64 / 1024.0);
    println!("lockfree_stack OK");
}
