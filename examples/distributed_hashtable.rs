//! The interlocked hash table (paper future work, ref [16]) served across
//! locales: a mixed get/put/remove workload with skewed keys, bucket
//! locality stats, and EBR churn.
//!
//! ```bash
//! cargo run --release --example distributed_hashtable -- --locales 8 --ops 30000
//! ```

use pgas_nb::collections::InterlockedHashTable;
use pgas_nb::epoch::EpochManager;
use pgas_nb::pgas::{coforall_locales, coforall_tasks, here, Machine, NicModel, Pgas};
use pgas_nb::util::cli::Args;
use pgas_nb::util::rng::Xoshiro256pp;
use pgas_nb::util::table::{fmt_ops, Table};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let locales = args.get_usize("locales", 8);
    let tasks = args.get_usize("tasks", 2);
    let ops = args.get_usize("ops", 30_000);
    let keyspace = args.get_u64("keys", 4096);

    let pgas = Pgas::new(Machine::new(locales, tasks), NicModel::aries_no_network_atomics());
    let em = EpochManager::new(Arc::clone(&pgas));
    let table: InterlockedHashTable<u64> =
        InterlockedHashTable::new(Arc::clone(&pgas), em.clone(), locales * 32);

    let hits = AtomicU64::new(0);
    let misses = AtomicU64::new(0);
    let local_ops = AtomicU64::new(0);
    let t0 = Instant::now();
    coforall_locales(pgas.machine(), |loc| {
        coforall_tasks(tasks, |tid| {
            let tok = table.register();
            let mut rng = Xoshiro256pp::new((loc.index() * tasks + tid) as u64 + 99);
            for i in 0..ops {
                // Zipf-ish skew: square the uniform sample.
                let u = rng.next_f64();
                let k = 1 + ((u * u) * (keyspace - 1) as f64) as u64;
                if table.home_of(k) == here() {
                    local_ops.fetch_add(1, Ordering::Relaxed);
                }
                match rng.next_below(10) {
                    0..=1 => {
                        table.insert(&tok, k, k * 7);
                    }
                    2 => {
                        table.remove(&tok, k);
                    }
                    _ => match table.get(&tok, k) {
                        Some(v) => {
                            assert_eq!(v, k * 7, "value integrity");
                            hits.fetch_add(1, Ordering::Relaxed);
                        }
                        None => {
                            misses.fetch_add(1, Ordering::Relaxed);
                        }
                    },
                }
                if i % 2048 == 0 {
                    tok.try_reclaim();
                }
            }
        });
    });
    let wall = t0.elapsed();

    let tok = table.register();
    let final_size = table.len(&tok);
    drop(tok);
    em.clear();
    let s = em.stats();
    assert_eq!(s.deferred, s.freed);

    let total = (locales * tasks * ops) as f64;
    println!("distributed_hashtable: {} buckets over {locales} locales", table.num_buckets());
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["ops/s (wall)".into(), fmt_ops(total / wall.as_secs_f64())]);
    t.row(&["lookup hit rate".into(), format!(
        "{:.1}%",
        100.0 * hits.load(Ordering::Relaxed) as f64
            / (hits.load(Ordering::Relaxed) + misses.load(Ordering::Relaxed)).max(1) as f64
    )]);
    t.row(&["bucket-local ops".into(), format!(
        "{:.1}%",
        100.0 * local_ops.load(Ordering::Relaxed) as f64 / total
    )]);
    t.row(&["final size".into(), final_size.to_string()]);
    t.row(&["epoch advances".into(), s.advances.to_string()]);
    t.row(&["entries reclaimed".into(), s.freed.to_string()]);
    println!("{}", t.render());
    println!("distributed_hashtable OK");
}
