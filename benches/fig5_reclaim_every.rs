//! Regenerates the paper's **Fig. 5**: EpochManager deletion workload with
//! `tryReclaim` on *every* iteration, ±network atomics.
//!
//! Expected shape: still scales with locales — losers shed on the local
//! flag long before reaching the global one.

use pgas_nb::coordinator::figures::{fig5, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = fig5(scale);
    println!("\n=== Fig 5: deletion, tryReclaim every iteration ({scale:?}) ===");
    println!("{}", t.render());
    println!("[csv]\n{}", t.to_csv());
}
