//! Regenerates the paper's **Fig. 6**: deletion with reclamation only at
//! the very end, with 0 / 50 / 100 % of objects owned by remote locales.
//!
//! Expected shape: remote objects cost more to reclaim, but the scatter
//! lists turn per-object RPCs into one bulk transfer per destination, so
//! the penalty stays a modest constant factor.

use pgas_nb::coordinator::figures::{fig6, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = fig6(scale);
    println!("\n=== Fig 6: deletion, reclamation at end, remote ratio sweep ({scale:?}) ===");
    println!("{}", t.render());
    println!("[csv]\n{}", t.to_csv());
}
