//! **Fig 11** (beyond the source paper): the service scenario. A
//! read-mostly, Zipf-skewed session-store mix (get/put/del/scan with key
//! churn) over the sharded hash table + Harris-list index, where every
//! remote op crosses the modeled fabric twice (request + reply AM). This
//! is the first bench whose *op path* rides the routed network, so the
//! `transit` and `queue` span layers — identically zero in the epoch
//! benches, see baselines/README — finally read nonzero here.
//!
//! Sweeps routed topologies (ring, dragonfly) over locale counts and
//! reports per-op-kind p50/p95/p99/p999 virtual-latency percentiles plus
//! the full `op = inject + transit + queue + epoch` decomposition.
//!
//! Acceptance, asserted on every run:
//! * per-kind op counts sum to the total, and every span closes;
//! * every point sees remote traffic, epoch advances, and reclamation;
//! * on the largest dragonfly point `transit` p50 and `queue` p99 are
//!   both nonzero (the baselines/README caveat is retired, not silently
//!   regressed back to zero).
//!
//! Also drives the same mix briefly against the *live* substrate (real
//! `InterlockedHashTable` + `LockFreeList`) on **both** execution
//! backends (`des` inline and `threads`-as-locales), printing measured
//! `wall_ns` next to the modeled `virtual_ns` and asserting per-kind
//! op-count conservation against a DES run of the same shape — printed
//! as a table only, never baselined: wall-clock numbers are
//! interleaving-dependent.
//!
//! Emits machine-readable `BENCH_service.json` (flat per-point keys so
//! `pgas-nb trace slo` can gate on it) next to the human table.

use pgas_nb::coordinator::figures::{service_cfg, Scale};
use pgas_nb::fabric::TopologyKind;
use pgas_nb::pgas::ExecKind;
use pgas_nb::util::bench::BenchRunner;
use pgas_nb::util::stats::LatencyHistogram;
use pgas_nb::util::table::Table;
use pgas_nb::workloads::{run_service, run_service_live_on, OpKind, ServiceConfig, ServiceResult};

struct Point {
    kind: TopologyKind,
    locales: usize,
    r: ServiceResult,
}

fn pcts(h: &LatencyHistogram, prefix: &str) -> String {
    format!(
        "\"{p}_p50_ns\": {}, \"{p}_p95_ns\": {}, \"{p}_p99_ns\": {}, \"{p}_p999_ns\": {}",
        h.percentile(50.0),
        h.percentile(95.0),
        h.percentile(99.0),
        h.percentile(99.9),
        p = prefix,
    )
}

fn kind_block(r: &ServiceResult, kind: OpKind, prefix: &str) -> String {
    let k = &r.by_kind[kind.index()];
    format!("\"{prefix}_ops\": {}, {}", k.count(), pcts(&k.op, prefix))
}

fn json_point(pt: &Point) -> String {
    let r = &pt.r;
    let l = &r.latency;
    format!(
        "    {{\"topology\": \"{}\", \"locales\": {}, \"makespan_ns\": {}, \"mops\": {:.4}, \
         \"ops\": {}, \"remote_ops\": {}, \"advances\": {}, \"freed\": {}, \
         \"queued_ns\": {}, \"transit_ns\": {}, {}, {}, {}, {}, {}, {}, {}, {}, {}}}",
        pt.kind.label(),
        pt.locales,
        r.makespan_ns,
        r.throughput_mops,
        r.total_ops,
        r.remote_ops,
        r.advances,
        r.freed,
        r.net.queued_ns,
        r.net.transit_ns,
        pcts(&l.op, "op"),
        pcts(&l.inject, "inject"),
        pcts(&l.transit, "transit"),
        pcts(&l.queue, "queue"),
        pcts(&l.epoch, "epoch"),
        kind_block(r, OpKind::Get, "get"),
        kind_block(r, OpKind::Put, "put"),
        kind_block(r, OpKind::Del, "del"),
        kind_block(r, OpKind::Scan, "scan"),
    )
}

fn main() {
    let mut b = BenchRunner::new("Fig 11: service-scenario tail latency (Zipf session store)");
    let scale = if b.quick() { Scale::Quick } else { Scale::Full };
    let locale_counts: &[usize] = if b.quick() { &[4, 8] } else { &[4, 8, 16, 32] };

    let mut t = Table::new(&[
        "topology",
        "locales",
        "mops",
        "remote%",
        "op_p50_us",
        "op_p99_us",
        "op_p999_us",
        "get_p99_us",
        "put_p99_us",
        "scan_p99_us",
        "transit_p50_us",
        "queue_p99_us",
        "epoch_p99_us",
        "advances",
        "freed",
    ]);
    let mut points: Vec<Point> = Vec::new();
    for kind in [TopologyKind::Ring, TopologyKind::Dragonfly] {
        for &locales in locale_counts {
            let r = run_service(service_cfg(scale, kind, locales));
            b.record_virtual(
                &format!("L={locales} topo={}", kind.label()),
                r.total_ops,
                r.makespan_ns as f64,
            );
            let us = |ns: u64| format!("{:.2}", ns as f64 / 1e3);
            t.row(&[
                kind.label().into(),
                locales.to_string(),
                format!("{:.2}", r.throughput_mops),
                format!("{:.1}", r.remote_ops as f64 * 100.0 / r.total_ops.max(1) as f64),
                us(r.latency.op.percentile(50.0)),
                us(r.latency.op.percentile(99.0)),
                us(r.latency.op.percentile(99.9)),
                us(r.by_kind[OpKind::Get.index()].op.percentile(99.0)),
                us(r.by_kind[OpKind::Put.index()].op.percentile(99.0)),
                us(r.by_kind[OpKind::Scan.index()].op.percentile(99.0)),
                us(r.latency.transit.percentile(50.0)),
                us(r.latency.queue.percentile(99.0)),
                us(r.latency.epoch.percentile(99.0)),
                r.advances.to_string(),
                r.freed.to_string(),
            ]);
            points.push(Point { kind, locales, r });
        }
    }

    println!("\n=== Fig 11: service scenario (DES, virtual time) ===");
    println!("{}", t.render());
    b.finish();

    // The acceptance invariants, checked on every run:
    for pt in &points {
        let r = &pt.r;
        let per_kind: u64 = r.by_kind.iter().map(|k| k.count()).sum();
        assert_eq!(per_kind, r.total_ops, "every op belongs to exactly one kind");
        assert_eq!(r.latency.count(), r.total_ops, "every span must close");
        assert!(r.remote_ops > 0, "Zipf homes must cross locales");
        assert!(r.advances > 0, "epoch must advance under the service mix");
        assert!(r.freed > 0, "deleted sessions must be reclaimed");
    }
    // The headline point: largest dragonfly. The op path crosses the
    // fabric, so the span layers the epoch benches leave at zero must be
    // nonzero here — this is the bench-side half of retiring the
    // baselines/README "transit/queue read zero" caveat.
    let last = *locale_counts.last().unwrap();
    let head = &points
        .iter()
        .find(|p| p.kind == TopologyKind::Dragonfly && p.locales == last)
        .unwrap()
        .r;
    assert!(
        head.latency.transit.percentile(50.0) > 0,
        "service ops ride the fabric: transit p50 must be nonzero"
    );
    assert!(
        head.latency.queue.percentile(99.0) > 0 && head.net.queued_ns > 0,
        "skewed homes must contend on links: queue p99 must be nonzero"
    );

    // The same mix against the live substrate (real collections) on BOTH
    // execution backends. Wall-clock latency is scheduling noise; what is
    // deterministic — and asserted — is the logical op mix: each task's
    // RNG stream never observes scheduling, so the per-kind op counts
    // must match a DES run of the same (seed, locales, tasks, ops) shape
    // exactly, on either backend (the conservation check).
    let mut live_cfg = service_cfg(Scale::Quick, TopologyKind::FullyConnected, 2);
    live_cfg.tasks_per_locale = 2;
    let live_ops = if b.quick() { 150 } else { 1_000 };
    let des_ref = run_service(ServiceConfig { ops_per_task: live_ops, ..live_cfg.clone() });
    let mut lt = Table::new(&["backend", "kind", "ops", "wall_p50_us", "wall_p99_us"]);
    for backend in ExecKind::ALL {
        let lr = run_service_live_on(&live_cfg, live_ops, backend);
        for (kind, name) in [
            (OpKind::Get, "get"),
            (OpKind::Put, "put"),
            (OpKind::Del, "del"),
            (OpKind::Scan, "scan"),
        ] {
            let h = &lr.by_kind[kind.index()];
            lt.row(&[
                backend.label().into(),
                name.into(),
                h.count().to_string(),
                format!("{:.2}", h.percentile(50.0) as f64 / 1e3),
                format!("{:.2}", h.percentile(99.0) as f64 / 1e3),
            ]);
        }
        println!(
            "live[{}]: {} ops, wall {:.2} ms vs modeled {:.2} ms, {} leaked, \
             arena banked/reused {}/{}",
            backend.label(),
            lr.total_ops,
            lr.wall_ns as f64 / 1e6,
            lr.virtual_ns as f64 / 1e6,
            lr.leaked,
            lr.arena_banked,
            lr.arena_reused,
        );
        assert_eq!(lr.leaked, 0, "live clear() must reclaim every session");
        assert_eq!(lr.total_ops as usize, 2 * 2 * live_ops);
        assert_eq!(
            lr.kind_counts(),
            des_ref.kind_counts(),
            "live-vs-DES op-count conservation must hold on the {} backend",
            backend.label()
        );
    }
    println!("\n=== live substrate, both backends (wall clock; never baselined) ===");
    println!("{}", lt.render());

    let cfg = service_cfg(scale, TopologyKind::Dragonfly, last);
    let json = format!(
        "{{\n  \"bench\": \"fig11_service\",\n  \"model\": \"aries_no_network_atomics\",\n  \
         \"tasks_per_locale\": {},\n  \"clients\": {},\n  \"ops_per_task\": {},\n  \
         \"skew\": \"0.99\",\n  \"mix\": \"get80_put12_del5_scan3\",\n  \
         \"churn_every\": {},\n  \"reclaim_every\": {},\n  \"buckets_per_locale\": {},\n  \
         \"seed\": {},\n  \"points\": [\n{}\n  ]\n}}\n",
        cfg.tasks_per_locale,
        cfg.clients,
        cfg.ops_per_task,
        cfg.churn_every,
        cfg.reclaim_every,
        cfg.buckets_per_locale,
        cfg.seed,
        points.iter().map(json_point).collect::<Vec<_>>().join(",\n")
    );
    match std::fs::write("BENCH_service.json", &json) {
        Ok(()) => println!("[wrote BENCH_service.json]"),
        Err(e) => eprintln!("[could not write BENCH_service.json: {e}]"),
    }
}
