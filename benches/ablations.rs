//! Ablations of the paper's §II design choices:
//!
//! 1. **FCFS two-level election** vs direct global contention (DES).
//! 2. **Wait-free limbo list** (one exchange) vs a mutex-protected list
//!    (real substrate, concurrent pushers).
//! 3. **Pointer compression** vs the 128-bit DCAS fallback for plain
//!    AtomicObject operations (real substrate).
//! 4. **Reclaim policy**: conservative three-stale vs the paper's
//!    two-stale drain (real substrate, churn workload).
//! 5. **PJRT kernel quiescence scan** vs the scalar per-token scan
//!    (real runtime, requires `make artifacts`).

use pgas_nb::atomics::{AtomicObject, StorageMode};
use pgas_nb::coordinator::figures::{ablation_election, Scale};
use pgas_nb::epoch::{EpochManager, LimboList, NodePool, ReclaimPolicy};
use pgas_nb::pgas::{LocaleId, Machine, NicModel, Pgas};
use pgas_nb::runtime::SharedReclaimScan;
use pgas_nb::util::bench::BenchRunner;
use std::sync::{Arc, Mutex};

fn main() {
    let scale = Scale::from_env();

    // --- 1. election ablation (DES) ---
    let t = ablation_election(scale);
    println!("\n=== Ablation: FCFS election vs direct global contention ({scale:?}) ===");
    println!("{}", t.render());

    let mut b = BenchRunner::new("substrate ablations");
    let n: u64 = if b.quick() { 20_000 } else { 200_000 };

    // --- 2. wait-free limbo list vs mutex list ---
    let pgas = Pgas::smp();
    {
        let pool = NodePool::new();
        let list = LimboList::new();
        b.case("limbo: wait-free push+drain (4 threads)", 4 * n, || {
            std::thread::scope(|s| {
                for _ in 0..4 {
                    let (pool, list, pgas) = (&pool, &list, &pgas);
                    s.spawn(move || {
                        for i in 0..n {
                            list.push(pool, pgas.alloc(LocaleId(0), i).erase());
                        }
                    });
                }
            });
            list.pop_all().drain(&pool, |e| unsafe { pgas.free_erased(e) });
        });
        let mlist: Mutex<Vec<pgas_nb::pgas::ErasedPtr>> = Mutex::new(Vec::new());
        b.case("limbo: mutex push+drain (4 threads)", 4 * n, || {
            std::thread::scope(|s| {
                for _ in 0..4 {
                    let (mlist, pgas) = (&mlist, &pgas);
                    s.spawn(move || {
                        for i in 0..n {
                            mlist.lock().unwrap().push(pgas.alloc(LocaleId(0), i).erase());
                        }
                    });
                }
            });
            for e in mlist.lock().unwrap().drain(..) {
                unsafe { pgas.free_erased(e) };
            }
        });
    }

    // --- 3. compression vs DCAS storage mode ---
    {
        let p = Pgas::new(Machine::new(2, 1), NicModel::aries_no_network_atomics());
        let x = p.alloc(LocaleId(0), 1u64);
        let y = p.alloc(LocaleId(1), 2u64);
        let compressed: AtomicObject<u64> =
            AtomicObject::with_mode(Arc::clone(&p), LocaleId(0), StorageMode::Compressed);
        let dcas: AtomicObject<u64> =
            AtomicObject::with_mode(Arc::clone(&p), LocaleId(0), StorageMode::Dcas);
        compressed.write(x);
        dcas.write(x);
        b.case("AtomicObject compressed: read+cas", 2 * n, || {
            for _ in 0..n {
                let cur = compressed.read();
                let next = if cur == x { y } else { x };
                compressed.compare_and_swap(cur, next);
            }
        });
        b.case("AtomicObject dcas-mode: read+cas", 2 * n, || {
            for _ in 0..n {
                let cur = dcas.read();
                let next = if cur == x { y } else { x };
                dcas.compare_and_swap(cur, next);
            }
        });
        unsafe {
            p.free(x);
            p.free(y);
        }
    }

    // --- 4. reclaim policy ---
    for (label, policy) in [
        ("policy conservative (3-stale)", ReclaimPolicy::Conservative),
        ("policy paper (2-stale)", ReclaimPolicy::PaperTwoStale),
    ] {
        let p = Pgas::new(Machine::new(2, 2), NicModel::aries_no_network_atomics());
        let em = EpochManager::with_policy(Arc::clone(&p), policy);
        let churn = n / 4;
        b.case(label, churn, || {
            let tok = em.register();
            for i in 0..churn {
                tok.pin();
                tok.defer_delete(p.alloc(LocaleId((i % 2) as u16), i));
                tok.unpin();
                if i % 256 == 0 {
                    tok.try_reclaim();
                }
            }
        });
        em.clear();
        assert_eq!(p.live_objects(), 0);
    }

    // --- 5. PJRT kernel scan vs scalar scan ---
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        let p = Pgas::new(Machine::new(8, 2), NicModel::aries_no_network_atomics());
        let em_scalar = EpochManager::new(Arc::clone(&p));
        let em_kernel = EpochManager::new(Arc::clone(&p));
        em_kernel
            .set_scanner(SharedReclaimScan::load_fitting(&dir, 8, 16, 512).unwrap())
            .ok()
            .unwrap();
        // Register a realistic token population on every locale.
        let mut toks_scalar = Vec::new();
        let mut toks_kernel = Vec::new();
        for l in 0..8u16 {
            for _ in 0..8 {
                toks_scalar.push(pgas_nb::pgas::with_locale(LocaleId(l), || em_scalar.register()));
                toks_kernel.push(pgas_nb::pgas::with_locale(LocaleId(l), || em_kernel.register()));
            }
        }
        let reps = if b.quick() { 50 } else { 500 };
        b.case("tryReclaim scalar scan (64 tokens, 8 locales)", reps, || {
            for _ in 0..reps {
                em_scalar.try_reclaim();
            }
        });
        b.case("tryReclaim PJRT kernel scan (64 tokens, 8 locales)", reps, || {
            for _ in 0..reps {
                em_kernel.try_reclaim();
            }
        });
        drop(toks_scalar);
        drop(toks_kernel);
    } else {
        eprintln!("(skipping PJRT scan ablation: run `make artifacts`)");
    }

    b.finish();
}
