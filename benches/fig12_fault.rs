//! **Fig 12** (beyond the source paper): the chaos sweep. The fig 9
//! remote-heavy reclamation workload runs on the dragonfly under
//! escalating fault schedules — faults-off control, 2% and 15% fabric
//! chaos (drops with retransmit, duplicate deliveries, bounded
//! reorders), a mid-run tail-locale crash survived via pin-lease
//! expiry, and a hierarchical-group-leader crash *under* chaos that
//! additionally forces a deterministic re-election. All schedules come
//! from `figures::fig12_cases`, so the CLI table (`pgas-nb bench
//! fig12`) and this bench emit identical numbers.
//!
//! Acceptance, asserted on every run:
//! * the control run observes zero fault activity and never touches the
//!   elastic-epoch machinery (lease expiries, flag steals, re-elections);
//! * chaos runs inject faults yet reclamation still frees objects and
//!   epochs still advance — and every run's conservation invariant
//!   (`deferred == freed + limbo_left + lost_to_crash`) holds;
//! * with the tail locale crashed while holding a pin, the lease expires,
//!   an advance lands after the crash (finite recovery time), and the
//!   crashed locale's limbo is accounted as lost, not leaked;
//! * the crashed group leader is replaced (re-elections > 0);
//! * the heaviest chaos point is bit-deterministic: a second run with
//!   the same plan reproduces makespan, counters and fabric totals.
//!
//! Emits machine-readable `BENCH_fault.json` next to the human table
//! (a CI artifact diffed against `baselines/BENCH_fault.json`).

use pgas_nb::coordinator::figures::{fig12_cases, fig12_locale_sweep, Scale, FIG12_FAULT_SEED};
use pgas_nb::sim::{run_epoch, EpochResult};
use pgas_nb::util::bench::BenchRunner;
use pgas_nb::util::table::Table;

struct Point {
    series: &'static str,
    locales: usize,
    r: EpochResult,
}

fn json_point(pt: &Point) -> String {
    let r = &pt.r;
    format!(
        "    {{\"series\": \"{}\", \"locales\": {}, \"makespan_ns\": {}, \"mops\": {:.4}, \
         \"dropped\": {}, \"dup\": {}, \"reordered\": {}, \"fault_ns\": {}, \
         \"deferred\": {}, \"freed\": {}, \"limbo_left\": {}, \"lost_to_crash\": {}, \
         \"lease_expiries\": {}, \"flag_steals\": {}, \"reelections\": {}, \
         \"recovery_ns\": {}, \"advances\": {}, \"lat\": {}}}",
        pt.series,
        pt.locales,
        r.makespan_ns,
        r.throughput_mops,
        r.net.faults_dropped,
        r.net.faults_dup,
        r.net.faults_reordered,
        r.net.fault_ns,
        r.deferred,
        r.freed,
        r.limbo_left,
        r.lost_to_crash,
        r.lease_expiries,
        r.flag_steals,
        r.reelections,
        r.recovery_ns.map_or_else(|| "null".into(), |ns| ns.to_string()),
        r.advances,
        r.latency.json(),
    )
}

fn main() {
    let mut b = BenchRunner::new("Fig 12: chaos sweep & crash recovery");
    let scale = if b.quick() { Scale::Quick } else { Scale::Full };

    let mut t = Table::new(&[
        "series",
        "locales",
        "makespan_ms",
        "mops",
        "dropped",
        "dup",
        "reord",
        "freed",
        "lost_crash",
        "lease_exp",
        "reelect",
        "recovery_ms",
    ]);
    let mut points: Vec<Point> = Vec::new();
    for &locales in &fig12_locale_sweep(scale) {
        for (series, cfg) in fig12_cases(scale, locales) {
            let r = run_epoch(cfg);
            b.record_virtual(&format!("L={locales} {series}"), r.total_iters, r.makespan_ns as f64);
            t.row(&[
                series.into(),
                locales.to_string(),
                format!("{:.2}", r.makespan_ns as f64 / 1e6),
                format!("{:.2}", r.throughput_mops),
                r.net.faults_dropped.to_string(),
                r.net.faults_dup.to_string(),
                r.net.faults_reordered.to_string(),
                r.freed.to_string(),
                r.lost_to_crash.to_string(),
                r.lease_expiries.to_string(),
                r.reelections.to_string(),
                r.recovery_ns
                    .map(|ns| format!("{:.2}", ns as f64 / 1e6))
                    .unwrap_or_else(|| "-".into()),
            ]);
            points.push(Point { series, locales, r });
        }
    }

    println!("\n=== Fig 12: fault schedules on the dragonfly ===");
    println!("{}", t.render());
    b.finish();

    // The acceptance invariants, checked on every run:
    let get = |series: &str, locales: usize| {
        &points.iter().find(|p| p.series == series && p.locales == locales).unwrap().r
    };
    for &locales in &fig12_locale_sweep(scale) {
        let quiet = get("none", locales);
        assert_eq!(
            quiet.net.faults_dropped
                + quiet.net.faults_dup
                + quiet.net.faults_reordered
                + quiet.net.fault_ns,
            0,
            "faults-off control observed fault activity"
        );
        assert_eq!(
            quiet.lease_expiries + quiet.flag_steals + quiet.reelections + quiet.lost_to_crash,
            0,
            "faults-off control touched the elastic-epoch machinery"
        );
        for series in ["chaos-20k", "chaos-150k"] {
            let r = get(series, locales);
            assert!(
                r.net.faults_dropped + r.net.faults_dup + r.net.faults_reordered > 0,
                "{series}: chaos plan injected nothing"
            );
            assert!(r.freed > 0 && r.advances > 0, "{series}: reclamation starved under chaos");
        }
        let crashed = get("crash+lease", locales);
        assert!(crashed.lease_expiries > 0, "the dead locale's pin was never expired");
        assert!(crashed.recovery_ns.is_some(), "no epoch advance after the tail crash");
        assert!(crashed.lost_to_crash > 0, "crashed locale should strand its limbo");
        let leader = get("crash+chaos-50k", locales);
        assert!(leader.reelections > 0, "crashed group leader was never replaced");
        assert!(leader.recovery_ns.is_some(), "no epoch advance after the leader crash");
    }
    // Bit-determinism of the heaviest chaos point: same plan, same run.
    let last = *fig12_locale_sweep(scale).last().unwrap();
    let (_, cfg) = fig12_cases(scale, last).remove(2);
    let again = run_epoch(cfg);
    let first = get("chaos-150k", last);
    assert_eq!(first.makespan_ns, again.makespan_ns, "chaos rerun must be deterministic");
    assert_eq!(first.net, again.net, "chaos rerun fabric totals must match");
    assert_eq!(
        (first.deferred, first.freed, first.advances),
        (again.deferred, again.freed, again.advances),
        "chaos rerun protocol counters must match"
    );
    let largest = *fig12_locale_sweep(scale).last().unwrap();
    println!(
        "\nL={largest}: crash+lease recovered in {:.2} ms (lease expiries {}), \
         leader crash re-elected {} time(s) under 5% chaos",
        get("crash+lease", largest).recovery_ns.unwrap_or(0) as f64 / 1e6,
        get("crash+lease", largest).lease_expiries,
        get("crash+chaos-50k", largest).reelections,
    );

    let json = format!(
        "{{\n  \"bench\": \"fig12_fault\",\n  \"model\": \"aries_no_network_atomics\",\n  \
         \"workload\": \"reclaim_every_64_remote50_dragonfly\",\n  \"fault_seed\": {},\n  \
         \"points\": [\n{}\n  ]\n}}\n",
        FIG12_FAULT_SEED,
        points.iter().map(json_point).collect::<Vec<_>>().join(",\n")
    );
    match std::fs::write("BENCH_fault.json", &json) {
        Ok(()) => println!("[wrote BENCH_fault.json]"),
        Err(e) => eprintln!("[could not write BENCH_fault.json: {e}]"),
    }
}
