//! Regenerates the paper's **Fig. 7**: read-only pin/unpin workload (no
//! deletion), ±network atomics.
//!
//! Expected shape: privatization makes every access locale-local, so
//! performance is flat per locale and aggregate throughput scales
//! linearly; network atomics tax the (local) pin/unpin atomics heavily.

use pgas_nb::coordinator::figures::{fig7, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = fig7(scale);
    println!("\n=== Fig 7: read-only workload ({scale:?}) ===");
    println!("{}", t.render());
    println!("[csv]\n{}", t.to_csv());
}
