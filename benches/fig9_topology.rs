//! **Fig 9** (beyond the source paper): interconnect-topology sensitivity
//! of the epoch-reclamation workload. The same remote-heavy
//! `DeleteReclaimEvery` trace is replayed on the DES testbed over four
//! wirings — `flat` (the zero-cost crossbar, i.e. the pre-fabric model),
//! `fully-connected`, `ring`, and the Aries-like `dragonfly` — sweeping
//! locale counts. Virtual-time totals must separate measurably across
//! the real topologies while `flat` reproduces the pre-fabric numbers
//! (zero transit, zero queueing) exactly; the per-link counters (hops,
//! busy time, queueing) show *why* each wiring costs what it does.
//!
//! Emits machine-readable `BENCH_topology.json` next to the human table
//! (a CI artifact alongside `BENCH_aggregation.json`).

use pgas_nb::fabric::TopologyKind;
use pgas_nb::fault::FaultPlan;
use pgas_nb::pgas::{NicModel, DEFAULT_AGG_CAPACITY};
use pgas_nb::sim::{run_epoch, Adaptivity, EpochConfig, EpochResult, EpochWorkload};
use pgas_nb::util::bench::BenchRunner;
use pgas_nb::util::table::Table;

struct Point {
    kind: TopologyKind,
    locales: usize,
    r: EpochResult,
}

fn run_point(kind: TopologyKind, locales: usize, objs_per_task: usize) -> Point {
    let cfg = EpochConfig {
        workload: EpochWorkload::DeleteReclaimEvery(256),
        model: NicModel::aries_no_network_atomics(),
        locales,
        tasks_per_locale: 8,
        objs_per_task,
        remote_ratio: 0.5,
        fcfs_local_election: true,
        slow_locale: None,
        slow_factor: 8,
        stalled_task: None,
        topology: kind,
        agg_capacity: DEFAULT_AGG_CAPACITY,
        adaptive: Adaptivity::default(),
        faults: FaultPlan::none(),
        seed: 29,
    };
    Point { kind, locales, r: run_epoch(cfg) }
}

fn json_point(pt: &Point) -> String {
    let r = &pt.r;
    format!(
        "    {{\"topology\": \"{}\", \"locales\": {}, \"makespan_ns\": {}, \"mops\": {:.4}, \
         \"net_messages\": {}, \"net_hops\": {}, \"net_bytes\": {}, \"transit_ns\": {}, \
         \"queued_ns\": {}, \"links_used\": {}, \"max_link_busy_ns\": {}, \
         \"max_link_wait_ns\": {}, \"lat\": {}}}",
        pt.kind.label(),
        pt.locales,
        r.makespan_ns,
        r.throughput_mops,
        r.net.messages,
        r.net.hops,
        r.net.bytes,
        r.net.transit_ns,
        r.net.queued_ns,
        r.net.links_used,
        r.net.max_link_busy_ns,
        r.net.max_link_wait_ns,
        r.latency.json(),
    )
}

fn main() {
    let mut b = BenchRunner::new("Fig 9: interconnect topology sensitivity (epoch reclamation)");
    let objs_per_task: usize = if b.quick() { 1_024 } else { 4_096 };
    let locale_counts: &[usize] = if b.quick() { &[4, 8] } else { &[4, 8, 16, 32] };

    let mut t = Table::new(&[
        "topology",
        "locales",
        "makespan_ms",
        "mops",
        "net_msgs",
        "mean_hops",
        "transit_ms",
        "queued_ms",
        "hot_link_busy_ms",
    ]);
    let mut points: Vec<Point> = Vec::new();
    for &locales in locale_counts {
        for kind in TopologyKind::ALL {
            let pt = run_point(kind, locales, objs_per_task);
            b.record_virtual(
                &format!("L={locales} topo={} reclaim/256 remote50%", kind.label()),
                pt.r.total_iters,
                pt.r.makespan_ns as f64,
            );
            t.row(&[
                kind.label().into(),
                locales.to_string(),
                format!("{:.2}", pt.r.makespan_ns as f64 / 1e6),
                format!("{:.2}", pt.r.throughput_mops),
                pt.r.net.messages.to_string(),
                format!("{:.2}", pt.r.net.hops as f64 / pt.r.net.messages.max(1) as f64),
                format!("{:.2}", pt.r.net.transit_ns as f64 / 1e6),
                format!("{:.2}", pt.r.net.queued_ns as f64 / 1e6),
                format!("{:.2}", pt.r.net.max_link_busy_ns as f64 / 1e6),
            ]);
            points.push(pt);
        }
    }

    println!("\n=== Fig 9: topology sweep (remote-heavy epoch reclamation) ===");
    println!("{}", t.render());
    b.finish();

    // The acceptance invariants, checked on every run:
    for &locales in locale_counts {
        let get = |kind: TopologyKind| {
            &points.iter().find(|p| p.kind == kind && p.locales == locales).unwrap().r
        };
        let flat = get(TopologyKind::FlatZero);
        assert_eq!(flat.net.transit_ns, 0, "flat must reproduce the pre-fabric model");
        assert_eq!(flat.net.queued_ns, 0);
        for kind in [TopologyKind::FullyConnected, TopologyKind::Ring, TopologyKind::Dragonfly] {
            let r = get(kind);
            assert!(
                r.makespan_ns > flat.makespan_ns,
                "L={locales} {}: real wiring must be measurably slower than flat",
                kind.label()
            );
        }
    }
    let headline = |kind: TopologyKind| {
        let last = *locale_counts.last().unwrap();
        points.iter().find(|p| p.kind == kind && p.locales == last).unwrap().r.makespan_ns as f64
    };
    let flat_ms = headline(TopologyKind::FlatZero);
    println!(
        "\nvirtual-time vs flat (L={}): fully-connected {:.2}x, ring {:.2}x, dragonfly {:.2}x",
        locale_counts.last().unwrap(),
        headline(TopologyKind::FullyConnected) / flat_ms,
        headline(TopologyKind::Ring) / flat_ms,
        headline(TopologyKind::Dragonfly) / flat_ms,
    );

    let json = format!(
        "{{\n  \"bench\": \"fig9_topology\",\n  \"model\": \"aries_no_network_atomics\",\n  \
         \"workload\": \"reclaim_every_256_remote50\",\n  \"objs_per_task\": {},\n  \
         \"points\": [\n{}\n  ]\n}}\n",
        objs_per_task,
        points.iter().map(json_point).collect::<Vec<_>>().join(",\n")
    );
    match std::fs::write("BENCH_topology.json", &json) {
        Ok(()) => println!("[wrote BENCH_topology.json]"),
        Err(e) => eprintln!("[could not write BENCH_topology.json: {e}]"),
    }
}
