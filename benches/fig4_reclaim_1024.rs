//! Regenerates the paper's **Fig. 4**: EpochManager deletion workload with
//! `tryReclaim` invoked once per 1024 iterations, ±network atomics.
//!
//! Expected shape: throughput scales with locales in both modes; the FCFS
//! election keeps the global-epoch locale un-swamped.

use pgas_nb::coordinator::figures::{fig4, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = fig4(scale);
    println!("\n=== Fig 4: deletion, tryReclaim per 1024 iterations ({scale:?}) ===");
    println!("{}", t.render());
    println!("[csv]\n{}", t.to_csv());
}
