//! **Fig 8** (beyond the source paper — the follow-up work's aggregation
//! curve, arXiv:2112.00068): a remote-`defer_delete`-heavy workload swept
//! over the destination-buffered aggregation capacity {1, 64, 256, 1024}
//! × locales. Capacity 1 is the unbuffered baseline: every remote-owned
//! deferral migrates to its owner immediately, one bulk-of-one PUT + one
//! AM per object. Larger buffers coalesce migrations into one transfer
//! per destination, so the AM count collapses and modeled comm time
//! (`virtual_ns`) drops with it; the new `aggregated_ops`/`flushes` NIC
//! counters prove the coalescing happened.
//!
//! The capacity sweep runs on the deterministic DES backend
//! (bit-identical to the committed baselines); a representative point
//! then re-runs on the threads-as-locales backend with an op-count
//! conservation assert, printing measured `wall_ms` next to the modeled
//! virtual time. Wall-clock is interleaving-dependent and never
//! baselined.
//!
//! Emits machine-readable `BENCH_aggregation.json` next to the human
//! table (the perf-trajectory seed for CI).

use pgas_nb::epoch::{EpochManager, ReclaimPolicy};
use pgas_nb::fabric::TopologyKind;
use pgas_nb::pgas::{coforall_locales, ExecKind, LocaleId, Machine, NicModel, NicSnapshot, Pgas};
use pgas_nb::util::bench::BenchRunner;
use pgas_nb::util::table::Table;
use std::sync::Arc;
use std::time::Instant;

struct Point {
    locales: usize,
    capacity: usize,
    ops: u64,
    freed: u64,
    wall_ns: u64,
    comm: NicSnapshot,
    advances: u64,
    migrated: u64,
    migration_flushes: u64,
    arena_banked: u64,
    arena_reused: u64,
}

/// Every locale defers `objs_per_locale` objects owned by *other*
/// locales (rotating owner), reclaiming periodically — the hot remote
/// path of the epoch manager. Runs on either execution backend: the
/// sweep stays on `Des` (bit-identical to the committed baselines), the
/// conservation point re-runs on `Threads`.
fn run_point(locales: usize, capacity: usize, objs_per_locale: usize, backend: ExecKind) -> Point {
    let p = Pgas::with_backend(
        Machine::new(locales, 2),
        NicModel::aries_no_network_atomics(),
        TopologyKind::FlatZero.build(locales),
        backend,
    );
    let em = EpochManager::with_config(Arc::clone(&p), ReclaimPolicy::default(), capacity);
    let t0 = Instant::now();
    coforall_locales(p.machine(), |loc| {
        let tok = em.register();
        for i in 0..objs_per_locale {
            tok.pin();
            // Owner is always a *different* locale: the remote-heavy case.
            let owner = LocaleId(((loc.index() + 1 + i % (locales - 1)) % locales) as u16);
            tok.defer_delete(p.alloc(owner, i as u64));
            tok.unpin();
            if i % 512 == 0 {
                tok.try_reclaim();
            }
        }
    });
    em.clear();
    let wall_ns = t0.elapsed().as_nanos() as u64;
    assert_eq!(p.live_objects(), 0, "aggregation must not leak");
    let s = em.stats();
    let ops = (locales * objs_per_locale) as u64;
    assert_eq!(s.freed, ops, "every deferral reclaimed exactly once");
    let (arena_banked, arena_reused) = p.arena_stats();
    Point {
        locales,
        capacity,
        ops,
        freed: s.freed,
        wall_ns,
        comm: p.comm_totals(),
        advances: s.advances,
        migrated: s.migrated,
        migration_flushes: s.migration_flushes,
        arena_banked,
        arena_reused,
    }
}

fn json_point(pt: &Point) -> String {
    format!(
        "    {{\"locales\": {}, \"capacity\": {}, \"ops\": {}, \"ams\": {}, \"puts\": {}, \
         \"bytes\": {}, \"virtual_ns\": {}, \"aggregated_ops\": {}, \"flushes\": {}, \
         \"advances\": {}, \"migrated\": {}, \"migration_flushes\": {}, \"wall_ns\": {}}}",
        pt.locales,
        pt.capacity,
        pt.ops,
        pt.comm.ams,
        pt.comm.puts,
        pt.comm.bytes,
        pt.comm.virtual_ns,
        pt.comm.aggregated_ops,
        pt.comm.flushes,
        pt.advances,
        pt.migrated,
        pt.migration_flushes,
        pt.wall_ns
    )
}

fn main() {
    let mut b = BenchRunner::new("Fig 8: destination-buffered aggregation of remote deferrals");
    let objs_per_locale: usize = if b.quick() { 2_048 } else { 8_192 };
    let capacities = [1usize, 64, 256, 1024];
    let locale_counts = [4usize, 8];

    let mut t = Table::new(&[
        "locales",
        "capacity",
        "ams",
        "puts",
        "virtual_ms",
        "agg_ops",
        "flushes",
        "am_reduction",
        "wall_ms",
    ]);
    let mut points: Vec<Point> = Vec::new();
    for &locales in &locale_counts {
        let mut baseline_ams = 0u64;
        for &capacity in &capacities {
            let pt = run_point(locales, capacity, objs_per_locale, ExecKind::Des);
            b.record_virtual(
                &format!("L={locales} cap={capacity} remote defer_delete"),
                pt.ops,
                pt.comm.virtual_ns as f64,
            );
            if capacity == 1 {
                baseline_ams = pt.comm.ams;
            }
            let reduction = if pt.comm.ams > 0 { baseline_ams as f64 / pt.comm.ams as f64 } else { 0.0 };
            t.row(&[
                locales.to_string(),
                capacity.to_string(),
                pt.comm.ams.to_string(),
                pt.comm.puts.to_string(),
                format!("{:.2}", pt.comm.virtual_ns as f64 / 1e6),
                pt.comm.aggregated_ops.to_string(),
                pt.comm.flushes.to_string(),
                format!("{reduction:.1}x"),
                format!("{:.2}", pt.wall_ns as f64 / 1e6),
            ]);
            points.push(pt);
        }
    }

    println!("\n=== Fig 8: aggregation capacity sweep (remote-heavy deferral workload) ===");
    println!("{}", t.render());
    b.finish();

    // Headline: the acceptance ratio for the largest machine in the sweep.
    let base = points.iter().find(|p| p.locales == 4 && p.capacity == 1).unwrap();
    let best = points.iter().find(|p| p.locales == 4 && p.capacity == 1024).unwrap();
    println!(
        "\nAM reduction (L=4, cap 1024 vs 1): {:.1}x  ({} -> {} AMs); modeled comm {:.2} ms -> {:.2} ms",
        base.comm.ams as f64 / best.comm.ams.max(1) as f64,
        base.comm.ams,
        best.comm.ams,
        base.comm.virtual_ns as f64 / 1e6,
        best.comm.virtual_ns as f64 / 1e6,
    );

    // The representative point again on the threads-as-locales backend:
    // real progress threads and per-locale arenas, with wall-clock next
    // to the modeled time charged by the same NIC path. The logical
    // workload is schedule-independent, so ops and freed must match the
    // DES run exactly (op-count conservation) and nothing may leak
    // (run_point asserts live_objects == 0 on both backends).
    let des_ref = points.iter().find(|p| p.locales == 4 && p.capacity == 256).unwrap();
    let live = run_point(4, 256, objs_per_locale, ExecKind::Threads);
    assert_eq!(live.ops, des_ref.ops, "threads backend must run the same logical ops");
    assert_eq!(live.freed, des_ref.freed, "every deferral reclaimed once on either backend");
    assert!(live.arena_banked > 0, "threads backend banks freed blocks in locale arenas");
    println!(
        "\n=== threads backend (L=4, cap 256; wall clock, never baselined) ===\n\
         ops {} freed {}  wall {:.2} ms vs modeled {:.2} ms  arena banked/reused {}/{}",
        live.ops,
        live.freed,
        live.wall_ns as f64 / 1e6,
        live.comm.virtual_ns as f64 / 1e6,
        live.arena_banked,
        live.arena_reused,
    );

    let json = format!(
        "{{\n  \"bench\": \"fig8_aggregation\",\n  \"model\": \"aries_no_network_atomics\",\n  \
         \"objs_per_locale\": {},\n  \"points\": [\n{}\n  ]\n}}\n",
        objs_per_locale,
        points.iter().map(json_point).collect::<Vec<_>>().join(",\n")
    );
    match std::fs::write("BENCH_aggregation.json", &json) {
        Ok(()) => println!("[wrote BENCH_aggregation.json]"),
        Err(e) => eprintln!("[could not write BENCH_aggregation.json: {e}]"),
    }
}
