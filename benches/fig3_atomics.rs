//! Regenerates the paper's **Fig. 3**: `AtomicObject` (with and without
//! ABA protection) vs Chapel's `atomic int`, in shared and distributed
//! memory, with and without RDMA network atomics.
//!
//! Expected shape (paper §III-A): AtomicObject == atomic int everywhere;
//! AtomicObject(ABA) pays a constant overhead locally and matches the
//! no-network-atomics baseline remotely; all series scale linearly.

use pgas_nb::coordinator::figures::{fig3, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = fig3(scale);
    println!("\n=== Fig 3: AtomicObject vs atomic int ({scale:?}) ===");
    println!("{}", t.render());
    println!("[csv]\n{}", t.to_csv());
}
