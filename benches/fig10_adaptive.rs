//! **Fig 10** (beyond the source paper): the congestion-adaptive fabric
//! under the epoch hot-spot workload. Every task runs `tryReclaim` every
//! iteration with half its deferrals remote, so election/advance traffic
//! funnels into locale 0 — the worst case the paper's flat protocol
//! leaves on the table. `minimal+fixed` replays that baseline (minimal
//! routing, fixed-capacity aggregation, flat advance); `adaptive` turns
//! on the three closed-loop knobs together: UGAL detours around
//! congested global links, deadline/backpressure-driven migration flush,
//! and the hierarchical (group-leader) epoch advance.
//!
//! Acceptance, asserted on every run:
//! * with the knobs OFF the trace is the pre-adaptive one (zero detours,
//!   zero migrations);
//! * on the dragonfly hot spot the adaptive mode cuts modeled completion
//!   time or the worst per-message link wait by ≥ 20 %;
//! * the hierarchical advance receives strictly fewer AMs per advance at
//!   the global-epoch home than the flat protocol.
//!
//! Emits machine-readable `BENCH_adaptive.json` next to the human table
//! (a CI artifact alongside `BENCH_topology.json`).

use pgas_nb::coordinator::figures::fig10_adaptive;
use pgas_nb::fabric::TopologyKind;
use pgas_nb::fault::FaultPlan;
use pgas_nb::pgas::NicModel;
use pgas_nb::sim::{run_epoch, Adaptivity, EpochConfig, EpochResult, EpochWorkload};
use pgas_nb::util::bench::BenchRunner;
use pgas_nb::util::table::Table;

struct Point {
    kind: TopologyKind,
    adaptive: bool,
    locales: usize,
    r: EpochResult,
}

fn mode_label(adaptive: bool) -> &'static str {
    if adaptive {
        "adaptive"
    } else {
        "minimal+fixed"
    }
}

fn run_point(kind: TopologyKind, adaptive: bool, locales: usize, objs_per_task: usize) -> Point {
    let cfg = EpochConfig {
        workload: EpochWorkload::DeleteReclaimEvery(1),
        model: NicModel::aries_no_network_atomics(),
        locales,
        tasks_per_locale: 8,
        objs_per_task,
        remote_ratio: 0.5,
        fcfs_local_election: true,
        slow_locale: None,
        slow_factor: 8,
        stalled_task: None,
        topology: kind,
        agg_capacity: 256,
        adaptive: if adaptive { fig10_adaptive() } else { Adaptivity::default() },
        faults: FaultPlan::none(),
        seed: 31,
    };
    Point { kind, adaptive, locales, r: run_epoch(cfg) }
}

fn json_point(pt: &Point) -> String {
    let r = &pt.r;
    format!(
        "    {{\"mode\": \"{}\", \"topology\": \"{}\", \"locales\": {}, \"makespan_ns\": {}, \
         \"mops\": {:.4}, \"max_link_wait_ns\": {}, \"queued_ns\": {}, \"detours\": {}, \
         \"ams_rx_home\": {}, \"advances\": {}, \"migrated\": {}, \"migration_flushes\": {}, \
         \"lat\": {}}}",
        mode_label(pt.adaptive),
        pt.kind.label(),
        pt.locales,
        r.makespan_ns,
        r.throughput_mops,
        r.net.max_link_wait_ns,
        r.net.queued_ns,
        r.net.detours,
        r.ams_rx_home,
        r.advances,
        r.migrated,
        r.migration_flushes,
        r.latency.json(),
    )
}

fn main() {
    let mut b = BenchRunner::new("Fig 10: congestion-adaptive fabric (epoch hot spot)");
    // Quick mode trades object count, not scale: the adaptive win (and the
    // headline assertion below) grows with locale count, so both modes keep
    // the L=32 hot-spot point and quick only shrinks the per-task work.
    let objs_per_task: usize = if b.quick() { 512 } else { 2_048 };
    let locale_counts: &[usize] = if b.quick() { &[8, 32] } else { &[8, 16, 32] };

    let mut t = Table::new(&[
        "mode",
        "topology",
        "locales",
        "makespan_ms",
        "mops",
        "max_link_wait_us",
        "detours",
        "ams_rx_home/adv",
        "migrated",
    ]);
    let mut points: Vec<Point> = Vec::new();
    for &locales in locale_counts {
        for kind in [TopologyKind::Ring, TopologyKind::Dragonfly] {
            for adaptive in [false, true] {
                let pt = run_point(kind, adaptive, locales, objs_per_task);
                b.record_virtual(
                    &format!("L={locales} topo={} {}", kind.label(), mode_label(adaptive)),
                    pt.r.total_iters,
                    pt.r.makespan_ns as f64,
                );
                t.row(&[
                    mode_label(adaptive).into(),
                    kind.label().into(),
                    locales.to_string(),
                    format!("{:.2}", pt.r.makespan_ns as f64 / 1e6),
                    format!("{:.2}", pt.r.throughput_mops),
                    format!("{:.2}", pt.r.net.max_link_wait_ns as f64 / 1e3),
                    pt.r.net.detours.to_string(),
                    format!("{:.1}", pt.r.ams_rx_home as f64 / pt.r.advances.max(1) as f64),
                    pt.r.migrated.to_string(),
                ]);
                points.push(pt);
            }
        }
    }

    println!("\n=== Fig 10: adaptive vs minimal+fixed (epoch hot spot) ===");
    println!("{}", t.render());
    b.finish();

    // The acceptance invariants, checked on every run:
    let get = |kind: TopologyKind, adaptive: bool, locales: usize| {
        &points
            .iter()
            .find(|p| p.kind == kind && p.adaptive == adaptive && p.locales == locales)
            .unwrap()
            .r
    };
    for &locales in locale_counts {
        for kind in [TopologyKind::Ring, TopologyKind::Dragonfly] {
            let base = get(kind, false, locales);
            assert_eq!(base.net.detours, 0, "knobs off must never detour");
            assert_eq!(base.migrated, 0, "knobs off must never migrate");
            // Same offered work either way.
            assert_eq!(base.total_iters, get(kind, true, locales).total_iters);
        }
    }
    // Headline: the dragonfly hot spot at the largest scale.
    let last = *locale_counts.last().unwrap();
    let base = get(TopologyKind::Dragonfly, false, last);
    let adap = get(TopologyKind::Dragonfly, true, last);
    let makespan_gain = 1.0 - adap.makespan_ns as f64 / base.makespan_ns as f64;
    let wait_gain = 1.0 - adap.net.max_link_wait_ns as f64 / base.net.max_link_wait_ns.max(1) as f64;
    println!(
        "\ndragonfly L={last}: completion {:.1}% better, worst link wait {:.1}% better, \
         {} detours, home AMs/advance {:.1} -> {:.1}",
        makespan_gain * 100.0,
        wait_gain * 100.0,
        adap.net.detours,
        base.ams_rx_home as f64 / base.advances.max(1) as f64,
        adap.ams_rx_home as f64 / adap.advances.max(1) as f64,
    );
    assert!(
        makespan_gain >= 0.20 || wait_gain >= 0.20,
        "adaptive mode must cut completion time or worst link wait by >= 20%: \
         makespan {:.1}%, wait {:.1}%",
        makespan_gain * 100.0,
        wait_gain * 100.0
    );
    let per_base = base.ams_rx_home as f64 / base.advances.max(1) as f64;
    let per_adap = adap.ams_rx_home as f64 / adap.advances.max(1) as f64;
    assert!(
        per_adap < per_base,
        "hierarchical advance must shed received AMs at the global home: {per_base:.1} -> {per_adap:.1}"
    );

    let json = format!(
        "{{\n  \"bench\": \"fig10_adaptive\",\n  \"model\": \"aries_no_network_atomics\",\n  \
         \"workload\": \"reclaim_every_1_remote50\",\n  \"objs_per_task\": {},\n  \
         \"adaptive\": {{\"ugal_threshold_ns\": 1000, \"flush_after_ns\": 100000, \
         \"backpressure_ns\": 25000, \"hier_group\": 4, \"agg_capacity\": 256}},\n  \
         \"points\": [\n{}\n  ]\n}}\n",
        objs_per_task,
        points.iter().map(json_point).collect::<Vec<_>>().join(",\n")
    );
    match std::fs::write("BENCH_adaptive.json", &json) {
        Ok(()) => println!("[wrote BENCH_adaptive.json]"),
        Err(e) => eprintln!("[could not write BENCH_adaptive.json: {e}]"),
    }
}
