//! Raw per-operation cost of every substrate primitive on the *real*
//! in-process implementation (wall clock, single host core). These are
//! the "raw overhead of both constructs" microbenchmarks of §III, and
//! the numbers the §Perf optimization pass tracks.

use pgas_nb::atomics::{AtomicObject, LocalAtomicObject};
use pgas_nb::collections::{InterlockedHashTable, LockFreeQueue, LockFreeStack};
use pgas_nb::epoch::{EpochManager, LocalEpochManager};
use pgas_nb::pgas::{GlobalPtr, LocaleId, Machine, NicModel, Pgas};
use pgas_nb::util::bench::BenchRunner;
use std::sync::Arc;

fn main() {
    let mut b = BenchRunner::new("substrate micro-costs (real implementation, wall clock)");
    let n: u64 = if b.quick() { 100_000 } else { 1_000_000 };

    let p = Pgas::new(Machine::new(4, 2), NicModel::aries_no_network_atomics());

    // --- atomics ---
    {
        let x = p.alloc(LocaleId(0), 1u64);
        let y = p.alloc(LocaleId(1), 2u64);
        let a: AtomicObject<u64> = AtomicObject::new(Arc::clone(&p), LocaleId(0));
        a.write(x);
        b.case("AtomicObject.read", n, || {
            for _ in 0..n {
                std::hint::black_box(a.read());
            }
        });
        b.case("AtomicObject.write", n, || {
            for _ in 0..n {
                a.write(x);
            }
        });
        b.case("AtomicObject.exchange", n, || {
            for _ in 0..n {
                std::hint::black_box(a.exchange(y));
            }
        });
        b.case("AtomicObject.cas (uncontended)", n, || {
            a.write(x);
            for _ in 0..n {
                let cur = a.read();
                a.compare_and_swap(cur, if cur == x { y } else { x });
            }
        });
        b.case("AtomicObject.read_aba", n, || {
            for _ in 0..n {
                std::hint::black_box(a.read_aba());
            }
        });
        b.case("AtomicObject.cas_aba (uncontended)", n, || {
            a.write_aba(x);
            for _ in 0..n {
                let cur = a.read_aba();
                a.compare_and_swap_aba(cur, if cur.get_object() == x { y } else { x });
            }
        });
        let la: LocalAtomicObject<u64> = LocalAtomicObject::new();
        la.write(x);
        b.case("LocalAtomicObject.read", n, || {
            for _ in 0..n {
                std::hint::black_box(la.read());
            }
        });
        b.case("LocalAtomicObject.cas", n, || {
            for _ in 0..n {
                let cur = la.read();
                la.compare_and_swap(cur, if cur == x { y } else { x });
            }
        });
        unsafe {
            p.free(x);
            p.free(y);
        }
    }

    // --- pointer compression ---
    {
        let w = pgas_nb::pgas::WidePtr::new(LocaleId(3), 0x7FFF_1234_5678);
        b.case("WidePtr.compress+decompress", n, || {
            for _ in 0..n {
                let c = std::hint::black_box(w).compress_exact();
                std::hint::black_box(pgas_nb::pgas::WidePtr::decompress(c));
            }
        });
    }

    // --- epoch manager ---
    {
        let em = EpochManager::new(Arc::clone(&p));
        let tok = em.register();
        b.case("EpochManager pin+unpin", n, || {
            for _ in 0..n {
                tok.pin();
                tok.unpin();
            }
        });
        let churn = n / 8;
        b.case("EpochManager defer_delete (incl. alloc)", churn, || {
            tok.pin();
            for i in 0..churn {
                tok.defer_delete(p.alloc(LocaleId(0), i));
            }
            tok.unpin();
            em.clear();
        });
        b.case("EpochManager try_reclaim (idle, 4 locales)", n / 64, || {
            for _ in 0..n / 64 {
                em.try_reclaim();
            }
        });
        drop(tok);

        let lem = LocalEpochManager::with_pgas(Arc::clone(&p));
        let ltok = lem.register();
        b.case("LocalEpochManager pin+unpin", n, || {
            for _ in 0..n {
                ltok.pin();
                ltok.unpin();
            }
        });
        b.case("LocalEpochManager try_reclaim (idle)", n / 16, || {
            for _ in 0..n / 16 {
                lem.try_reclaim();
            }
        });
    }

    // --- collections (single-task path) ---
    {
        let em = EpochManager::new(Arc::clone(&p));
        let stack = LockFreeStack::new(Arc::clone(&p), em.clone());
        let tok = stack.register();
        let ops = n / 8;
        b.case("LockFreeStack push+pop", 2 * ops, || {
            for i in 0..ops {
                stack.push(&tok, i);
            }
            for _ in 0..ops {
                stack.pop(&tok);
            }
            em.clear();
        });
        let q = LockFreeQueue::new(Arc::clone(&p), em.clone());
        b.case("LockFreeQueue enq+deq", 2 * ops, || {
            for i in 0..ops {
                q.enqueue(&tok, i);
            }
            for _ in 0..ops {
                q.dequeue(&tok);
            }
            em.clear();
        });
        let h: InterlockedHashTable<u64> = InterlockedHashTable::new(Arc::clone(&p), em.clone(), 256);
        b.case("InterlockedHashTable insert+get+remove", 3 * ops / 4, || {
            for k in 1..=ops / 4 {
                h.insert(&tok, k, k);
            }
            for k in 1..=ops / 4 {
                std::hint::black_box(h.get(&tok, k));
            }
            for k in 1..=ops / 4 {
                h.remove(&tok, k);
            }
            em.clear();
        });
        drop(tok);
    }

    // --- one-sided comm ---
    {
        let g = p.alloc(LocaleId(2), 0u64);
        b.case("pgas.get (remote, modeled)", n / 4, || {
            for _ in 0..n / 4 {
                std::hint::black_box(p.get(g));
            }
        });
        b.case("pgas.put (remote, modeled)", n / 4, || {
            for i in 0..n / 4 {
                p.put(g, i);
            }
        });
        unsafe { p.free(g) };
    }

    // GlobalPtr compression sanity so the optimizer can't elide types.
    let gp: GlobalPtr<u64> = GlobalPtr::nil();
    assert!(gp.is_nil());

    b.finish();
}
